//! Timing-speculative voltage over-scaling (§III-D).
//!
//! Algorithm 1 runs with the timing constraint relaxed to `rate × d_worst`
//! (the obtained voltages are optimal for that violation budget — the paper
//! modifies line 7 exactly this way). The post-P&R timing simulation then
//! prices every endpoint at the converged (T, V) and produces per-endpoint
//! timing-violation probabilities:
//!
//! * a path longer than the operating clock period fails whenever it is
//!   exercised (probability = its endpoint activity);
//! * a path inside the guardband (d_worst < d ≤ T_clk) fails only when a
//!   voltage-transient event [5] coincides with its activation — rare
//!   (`P_TRANSIENT` per cycle) and proportional to how deep into the
//!   guardband the path reaches.
//!
//! This is why Fig. 8's error curves stay near zero until ≈1.2× and spike
//! around 1.35×: the guardband silently absorbs early violations, then the
//! true wall arrives. The resulting error rates drive the ML workloads
//! (`crate::ml`).

use crate::activity::Activities;
use crate::config::Config;
use crate::flow::alg1::{self, Alg1Result};
use crate::flow::design::Design;
use crate::thermal::ThermalBackend;
use crate::timing::{Sta, StaCacheArena};

/// Per-cycle probability of a voltage-transient event deep enough to erase
/// the guardband (load transients are infrequent [5]).
pub const P_TRANSIENT: f64 = 2e-3;

/// Timing-error model extracted from the post-P&R simulation.
#[derive(Clone, Debug)]
pub struct ErrorModel {
    /// Violation probability per cycle for every endpoint.
    pub p_viol: Vec<f64>,
    /// Mean violation probability across endpoints (the aggregate rate the
    /// ML error injection consumes).
    pub mean_rate: f64,
    /// Fraction of endpoints past the hard wall (d > T_clk).
    pub hard_fraction: f64,
    /// Operating clock period (s).
    pub t_clk: f64,
}

impl ErrorModel {
    /// Expected number of timing errors over a job that clocks at `f_clk`
    /// for `duration_s`: the mean per-cycle violation probability times the
    /// cycle count. This is the quantity the fleet's overscaled-dynamic
    /// policy reports per job (and what `ml::expected_accuracy` maps to a
    /// quality figure).
    ///
    /// Degenerate inputs — a non-finite clock, a negative or non-finite
    /// duration — clamp to 0.0 expected errors instead of feeding negative
    /// or NaN counts into fleet telemetry.
    pub fn expected_errors(&self, f_clk: f64, duration_s: f64) -> f64 {
        if !f_clk.is_finite() || !duration_s.is_finite() {
            return 0.0;
        }
        (self.mean_rate * f_clk * duration_s).max(0.0)
    }
}

#[derive(Clone, Debug)]
pub struct OverscaleResult {
    pub rate: f64,
    pub alg1: Alg1Result,
    pub error: ErrorModel,
}

/// Run the over-scaling flow at CP-violation `rate` ≥ 1.0. The Algorithm-1
/// search and the post-P&R timing simulation share one [`StaCacheArena`],
/// so the error model prices the converged (T, V) off caches the search
/// already built.
#[deprecated(note = "construct flows through `flow::FlowSession::overscale`")]
pub fn overscale(
    design: &Design,
    cfg: &Config,
    backend: &mut dyn ThermalBackend,
    rate: f64,
) -> OverscaleResult {
    let sta = design.sta();
    let pm = design.power_model();
    let mut arena = StaCacheArena::new();
    let res = alg1::run_impl(design, &sta, &pm, cfg, backend, rate, &mut arena);
    let error = error_model_impl(design, &design.acts, &sta, cfg, &res, &mut arena);
    OverscaleResult {
        rate,
        alg1: res,
        error,
    }
}

/// Post-P&R timing simulation: endpoint arrivals at the converged (T, V)
/// versus the operating clock.
#[deprecated(note = "construct flows through `flow::FlowSession::overscale`")]
pub fn error_model(design: &Design, cfg: &Config, res: &Alg1Result) -> ErrorModel {
    let sta = design.sta();
    let mut arena = StaCacheArena::new();
    error_model_impl(design, &design.acts, &sta, cfg, res, &mut arena)
}

/// Arena-sharing form of [`error_model`].
#[deprecated(note = "construct flows through `flow::FlowSession::overscale`")]
pub fn error_model_with(
    design: &Design,
    sta: &Sta<'_>,
    cfg: &Config,
    res: &Alg1Result,
    arena: &mut StaCacheArena,
) -> ErrorModel {
    error_model_impl(design, &design.acts, sta, cfg, res, arena)
}

/// Post-P&R timing simulation behind `FlowSession::overscale`. `acts` is
/// passed explicitly (instead of read off the design) so activity-override
/// requests price endpoint activations at the requested α.
pub(crate) fn error_model_impl(
    design: &Design,
    acts: &Activities,
    sta: &Sta<'_>,
    cfg: &Config,
    res: &Alg1Result,
    arena: &mut StaCacheArena,
) -> ErrorModel {
    let timing = arena.analyze(sta, &res.temp, res.v_core, res.v_bram);
    let t_clk = res.d_worst * (1.0 + cfg.flow.guardband);
    let span = (t_clk - res.d_worst).max(1e-15);
    let mut p_viol = Vec::with_capacity(timing.endpoints.len());
    let mut hard = 0usize;
    for e in &timing.endpoints {
        // activation probability: activity of the endpoint's data input
        let p_act = design.nl.cells[e.cell as usize]
            .inputs
            .first()
            .map(|&n| acts.alpha[n as usize])
            .unwrap_or(0.0)
            .clamp(0.0, 1.0);
        let p = if e.arrival > t_clk {
            hard += 1;
            p_act
        } else if e.arrival > res.d_worst {
            p_act * P_TRANSIENT * ((e.arrival - res.d_worst) / span)
        } else {
            0.0
        };
        p_viol.push(p);
    }
    let mean_rate = if p_viol.is_empty() {
        0.0
    } else {
        p_viol.iter().sum::<f64>() / p_viol.len() as f64
    };
    ErrorModel {
        mean_rate,
        hard_fraction: hard as f64 / timing.endpoints.len().max(1) as f64,
        p_viol,
        t_clk,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::design::Effort;
    use crate::thermal::{NativeSolver, ThermalGrid};

    fn setup() -> (Design, Config, NativeSolver) {
        let mut cfg = Config::new();
        cfg.flow.t_amb = 40.0;
        cfg.thermal.theta_ja = 12.0;
        let d = Design::build("mkPktMerge", &cfg, Effort::Quick).unwrap();
        let solver = NativeSolver::new(
            ThermalGrid::calibrated(d.dev.rows, d.dev.cols, &cfg.thermal),
            &cfg.thermal,
        );
        (d, cfg, solver)
    }

    /// Direct-impl harness (the session facade is exercised by
    /// `tests/session.rs`; the unit tests pin the flow itself).
    fn run(d: &Design, cfg: &Config, backend: &mut dyn ThermalBackend, rate: f64) -> OverscaleResult {
        let sta = d.sta();
        let pm = d.power_model();
        let mut arena = StaCacheArena::new();
        let res = alg1::run_impl(d, &sta, &pm, cfg, backend, rate, &mut arena);
        let error = error_model_impl(d, &d.acts, &sta, cfg, &res, &mut arena);
        OverscaleResult { rate, alg1: res, error }
    }

    #[test]
    fn fig8_error_shape_quiet_then_spike() {
        let (d, cfg, mut solver) = setup();
        let r10 = run(&d, &cfg, &mut solver.clone(), 1.0);
        let r12 = run(&d, &cfg, &mut solver.clone(), 1.2);
        let r14 = run(&d, &cfg, &mut solver, 1.42);
        // no violation budget ⇒ error-free
        assert_eq!(r10.error.hard_fraction, 0.0);
        assert!(r10.error.mean_rate < 1e-12);
        // inside the guardband: tiny transient-coincident rate only
        assert!(r12.error.mean_rate < 1e-3, "rate@1.2 = {}", r12.error.mean_rate);
        assert_eq!(r12.error.hard_fraction, 0.0);
        // past the 1.36 guardband wall: *hard* violations appear (the spike
        // that drives the Fig. 8 accuracy cliff — transient-coincident rates
        // of ~1e-6 never materialize over a test set, hard rates do)
        assert!(r14.error.hard_fraction > 0.0);
        assert!(
            r14.error.mean_rate > r12.error.mean_rate * 2.5,
            "no spike: {} vs {}",
            r14.error.mean_rate,
            r12.error.mean_rate
        );
        // expected errors per cycle across all endpoints become macroscopic
        let expected_per_cycle =
            r14.error.mean_rate * r14.error.p_viol.len() as f64;
        assert!(expected_per_cycle > 1e-4, "per-cycle {expected_per_cycle}");
    }

    #[test]
    fn expected_errors_scale_with_cycles() {
        let m = ErrorModel {
            p_viol: vec![1e-6, 3e-6],
            mean_rate: 2e-6,
            hard_fraction: 0.0,
            t_clk: 1e-8,
        };
        let e = m.expected_errors(1e8, 10.0); // 1e9 cycles at 2e-6/cycle
        assert!((e - 2e3).abs() < 1e-9);
        assert_eq!(m.expected_errors(1e8, 0.0), 0.0);
        // degenerate inputs clamp to zero instead of poisoning telemetry
        assert_eq!(m.expected_errors(1e8, -5.0), 0.0);
        assert_eq!(m.expected_errors(f64::NAN, 10.0), 0.0);
        assert_eq!(m.expected_errors(f64::INFINITY, 10.0), 0.0);
        assert_eq!(m.expected_errors(1e8, f64::NAN), 0.0);
        assert_eq!(m.expected_errors(-1e8, 10.0), 0.0);
    }

    #[test]
    fn more_overscaling_more_power_saving() {
        let (d, cfg, mut solver) = setup();
        let mut prev = f64::INFINITY;
        for rate in [1.0, 1.15, 1.3] {
            let r = run(&d, &cfg, &mut solver.clone(), rate);
            assert!(r.alg1.power <= prev + 1e-12, "power not monotone at {rate}");
            prev = r.alg1.power;
        }
    }
}
