//! `FlowSession` — the unified, typed facade over every thermal-aware flow
//! entry point.
//!
//! Three PRs of growth left the paper's flows behind a sprawl of
//! positional-argument free functions (`alg1::run_with_arena`,
//! `alg2::run_naive_with`, `VoltageLut::build_rate`, `overscale::...`),
//! each caller hand-threading `Config` / `Design` / `StaCacheArena` /
//! effort in its own order. The session replaces that accidental interface
//! with one owner of the shared state and one typed request/outcome pair
//! per paper algorithm:
//!
//! * [`FlowSession::alg1`] — Algorithm 1, thermal-aware voltage selection
//!   (§III-B), with the §III-D `rate` knob;
//! * [`FlowSession::baseline`] — the fixed-rails thermal fixed point
//!   (nominal rails by default, or any rails for the Fig. 4/6/7
//!   activity-range re-evaluations);
//! * [`FlowSession::alg2`] / [`FlowSession::energy_opt`] — Algorithm 2,
//!   thermal-aware energy optimization (§III-C), with a [`Fidelity`] knob
//!   selecting the batched engine or the pre-refactor naive path;
//! * [`FlowSession::voltage_lut`] — the (T → V) table behind the dynamic
//!   scheme, with a [`LutSpec`] subsuming the safe sweep, the over-scaled
//!   sweep, and the degenerate fixed-rails table;
//! * [`FlowSession::overscale`] — the §III-D over-scaling flow plus its
//!   post-P&R timing-error model.
//!
//! ## Ownership and caching
//!
//! The session owns everything the flows share:
//!
//! * an [`Arc<Config>`] — the base operating condition; requests override
//!   ambient / θ_JA / activity per call without touching the base;
//! * a memoizing **design cache** keyed by `(benchmark, effort)`: the CAD
//!   pipeline (synthesize → pack → place → route → characterize) runs once
//!   per key, then every request reuses the placed design (`Arc<Design>`);
//! * the process-wide [`CharTable`] (via [`CharTable::shared`]);
//! * one reusable [`StaCacheArena`] **per cached design** (arenas intern
//!   per-device delay caches, so they must never cross designs) plus one
//!   thermal backend per (design, θ_JA) — both live as long as the session.
//!
//! Everything cached is *memoization only*: a session answers every request
//! bit-identically to a cold run of the legacy free functions
//! (`tests/session.rs` pins this differentially, including the Algorithm-2
//! search-effort counters).
//!
//! Known cost: the borrowed `Sta` / `PowerModel` views are rebuilt per
//! request (they borrow the design, so they cannot live in the cache next
//! to it without an owned-arena refactor of `timing`/`power`). Both are a
//! single O(netlist) pass — small against the dozens of full STA/thermal
//! evaluations any one flow request performs — so the facade keeps the
//! simpler shape until a profile says otherwise.
//!
//! ## Deprecation policy
//!
//! The legacy free functions survive as `#[deprecated]` shims so the
//! differential tests can pin the new API against the old one; non-test
//! code must not call them (CI greps for it). They will be removed once a
//! release has shipped with the session API.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::Arc;

use crate::activity::Activities;
use crate::chardb::CharTable;
use crate::config::Config;
use crate::faults::{
    self, AccuracyPoint, BramMap, FaultSpec, GuardbandStore, Injector, Protection, ShmooResult,
};
use crate::fleet::stream::{StreamConfig, StreamSim, StreamTelemetry};
use crate::fleet::trace::{CouplingSpec, Scenario};
use crate::flow::alg1::{self, Alg1Result};
use crate::flow::alg2::{self, Alg2Result};
use crate::flow::design::{Design, Effort};
use crate::flow::dynamic::{self, LutSweep, VoltageLut};
use crate::flow::error::FlowError;
use crate::flow::overscale::{self, ErrorModel};
use crate::runtime::select_backend;
use crate::thermal::{RcNetwork, ThermalBackend, ThermalDynamics};
use crate::timing::{ArenaStats, StaCacheArena};
use crate::util::{mix64, Xoshiro256};

// ------------------------------------------------------------ requests --

/// Evaluation fidelity for Algorithm 2: the batched, memoizing STA engine
/// or the pre-refactor per-probe path (kept for benchmarking and as the
/// differential baseline — results are bit-identical by construction).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Fidelity {
    /// Batched flat STA + prepared-power sweep + arena memoization.
    #[default]
    Fast,
    /// Pre-refactor per-probe evaluation (the CLI's `energy-opt --naive`).
    Naive,
}

/// What (T → V) table to build: subsumes the legacy
/// `VoltageLut::{build, build_rate, fixed}` constructors.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LutSpec {
    /// Safe ambient sweep (rate 1.0): one Algorithm-1 run per `step_c`
    /// from `t_amb_lo` to `t_amb_hi`.
    Sweep {
        t_amb_lo: f64,
        t_amb_hi: f64,
        step_c: f64,
    },
    /// Sweep with the §III-D CP-violation budget relaxing every run's
    /// timing constraint to `rate × d_worst`.
    SweepRate {
        t_amb_lo: f64,
        t_amb_hi: f64,
        step_c: f64,
        rate: f64,
    },
    /// Degenerate single-row table that always commands the given rails
    /// (the static scheme expressed as a controller input).
    Fixed { v_core: f64, v_bram: f64 },
}

/// Request for Algorithm 1 (thermal-aware voltage selection).
#[derive(Clone, Debug)]
pub struct Alg1Request {
    /// Benchmark name: the VTR-profile suite plus the ML accelerator
    /// profiles `lenet_systolic` and `hd_engine`.
    pub bench: String,
    /// Ambient temperature override (°C); `None` = the session config's.
    pub ambient: Option<f64>,
    /// θ_JA override (°C/W); `None` = the session config's.
    pub theta_ja: Option<f64>,
    /// Primary-input activity override; `None` = the session config's.
    pub alpha: Option<f64>,
    /// Allowed CP-delay violation (1.0 = none; §III-D over-scaling hook).
    pub rate: f64,
    /// Placer effort override; `None` = the session default.
    pub effort: Option<Effort>,
}

impl Alg1Request {
    /// Request with every override at the session default.
    ///
    /// # Examples
    ///
    /// ```
    /// use thermovolt::flow::Alg1Request;
    ///
    /// let req = Alg1Request { ambient: Some(40.0), ..Alg1Request::new("sha") };
    /// assert_eq!(req.bench, "sha");
    /// assert_eq!(req.rate, 1.0); // no CP-violation budget by default
    /// assert!(req.theta_ja.is_none());
    /// ```
    pub fn new(bench: impl Into<String>) -> Alg1Request {
        Alg1Request {
            bench: bench.into(),
            ambient: None,
            theta_ja: None,
            alpha: None,
            rate: 1.0,
            effort: None,
        }
    }
}

/// Request for the fixed-rails thermal fixed point (the baseline curve and
/// the activity-range re-evaluation of a chosen operating point).
#[derive(Clone, Debug)]
pub struct BaselineRequest {
    pub bench: String,
    pub ambient: Option<f64>,
    pub theta_ja: Option<f64>,
    pub alpha: Option<f64>,
    /// `(v_core, v_bram)` to hold fixed; `None` = the nominal rails (the
    /// paper's one-size-fits-all baseline).
    pub rails: Option<(f64, f64)>,
    pub effort: Option<Effort>,
}

impl BaselineRequest {
    /// Nominal-rails baseline (the paper's one-size-fits-all denominator).
    ///
    /// # Examples
    ///
    /// ```
    /// use thermovolt::flow::BaselineRequest;
    ///
    /// let req = BaselineRequest::new("mkPktMerge");
    /// assert!(req.rails.is_none()); // None ⇒ the nominal rails
    /// let fig4 = BaselineRequest { rails: Some((0.70, 0.85)), ..req };
    /// assert_eq!(fig4.rails, Some((0.70, 0.85)));
    /// ```
    pub fn new(bench: impl Into<String>) -> BaselineRequest {
        BaselineRequest {
            bench: bench.into(),
            ambient: None,
            theta_ja: None,
            alpha: None,
            rails: None,
            effort: None,
        }
    }
}

/// Request for Algorithm 2 (thermal-aware energy optimization).
#[derive(Clone, Debug)]
pub struct Alg2Request {
    pub bench: String,
    pub ambient: Option<f64>,
    pub theta_ja: Option<f64>,
    pub alpha: Option<f64>,
    /// Batched engine or the pre-refactor naive path.
    pub fidelity: Fidelity,
    /// Override for the §III-C pruning rules; `None` = the session
    /// config's `flow.prune`.
    pub prune: Option<bool>,
    pub effort: Option<Effort>,
}

impl Alg2Request {
    /// Request on the batched engine ([`Fidelity::Fast`]) with session
    /// defaults everywhere else.
    ///
    /// # Examples
    ///
    /// ```
    /// use thermovolt::flow::{Alg2Request, Fidelity};
    ///
    /// let req = Alg2Request::new("sha");
    /// assert_eq!(req.fidelity, Fidelity::Fast);
    /// let naive = Alg2Request { fidelity: Fidelity::Naive, ..req };
    /// assert_eq!(naive.fidelity, Fidelity::Naive); // the bench baseline
    /// ```
    pub fn new(bench: impl Into<String>) -> Alg2Request {
        Alg2Request {
            bench: bench.into(),
            ambient: None,
            theta_ja: None,
            alpha: None,
            fidelity: Fidelity::Fast,
            prune: None,
            effort: None,
        }
    }
}

/// Request for a (T → V) voltage lookup table.
#[derive(Clone, Debug)]
pub struct LutRequest {
    pub bench: String,
    pub theta_ja: Option<f64>,
    pub alpha: Option<f64>,
    pub spec: LutSpec,
    pub effort: Option<Effort>,
}

impl LutRequest {
    /// Table request for the given [`LutSpec`].
    ///
    /// # Examples
    ///
    /// ```
    /// use thermovolt::flow::{LutRequest, LutSpec};
    ///
    /// let spec = LutSpec::Sweep { t_amb_lo: 0.0, t_amb_hi: 80.0, step_c: 10.0 };
    /// let req = LutRequest::new("sha", spec);
    /// assert_eq!(req.spec, spec);
    /// assert!(req.theta_ja.is_none()); // session θ_JA unless overridden
    /// ```
    pub fn new(bench: impl Into<String>, spec: LutSpec) -> LutRequest {
        LutRequest {
            bench: bench.into(),
            theta_ja: None,
            alpha: None,
            spec,
            effort: None,
        }
    }
}

/// Request for the §III-D over-scaling flow (Algorithm 1 at a CP-violation
/// budget plus the post-P&R timing-error model at the converged (T, V)).
#[derive(Clone, Debug)]
pub struct OverscaleRequest {
    pub bench: String,
    pub ambient: Option<f64>,
    pub theta_ja: Option<f64>,
    pub alpha: Option<f64>,
    /// CP-delay violation budget, ≥ 1.0.
    pub rate: f64,
    pub effort: Option<Effort>,
}

impl OverscaleRequest {
    /// §III-D request at the given CP-violation budget (≥ 1.0).
    ///
    /// # Examples
    ///
    /// ```
    /// use thermovolt::flow::OverscaleRequest;
    ///
    /// let req = OverscaleRequest::new("lenet_systolic", 1.2);
    /// assert_eq!(req.rate, 1.2); // rails optimized for 1.2 × d_worst
    /// ```
    pub fn new(bench: impl Into<String>, rate: f64) -> OverscaleRequest {
        OverscaleRequest {
            bench: bench.into(),
            ambient: None,
            theta_ja: None,
            alpha: None,
            rate,
            effort: None,
        }
    }
}

/// Request for an RC thermal-network transient (`thermal::transient`): the
/// design's nominal-rails power step driven into a Foster network, returning
/// the settling point, response times and a decimated trajectory.
#[derive(Clone, Debug)]
pub struct TransientRequest {
    pub bench: String,
    pub ambient: Option<f64>,
    pub theta_ja: Option<f64>,
    pub alpha: Option<f64>,
    /// Dominant thermal time constant of the network (ms).
    pub tau_ms: f64,
    /// Foster stages (1 = the lumped single-pole plant, which settles
    /// bit-identically to the steady-state θ_JA backend).
    pub stages: usize,
    /// Integrator step (ms).
    pub dt_ms: f64,
    /// Simulated horizon (ms).
    pub horizon_ms: f64,
    pub effort: Option<Effort>,
}

impl TransientRequest {
    /// Defaults: τ = 3 s (die-scale inertia, [40]), 2 Foster stages, 50 ms
    /// steps over a 30 s horizon.
    ///
    /// # Examples
    ///
    /// ```
    /// use thermovolt::flow::TransientRequest;
    ///
    /// let req = TransientRequest { stages: 1, ..TransientRequest::new("sha") };
    /// assert_eq!(req.tau_ms, 3000.0);
    /// assert_eq!(req.stages, 1); // single pole ≡ the lumped θ_JA plant
    /// assert!(req.horizon_ms / req.dt_ms >= 100.0);
    /// ```
    pub fn new(bench: impl Into<String>) -> TransientRequest {
        TransientRequest {
            bench: bench.into(),
            ambient: None,
            theta_ja: None,
            alpha: None,
            tau_ms: 3000.0,
            stages: 2,
            dt_ms: 50.0,
            horizon_ms: 30_000.0,
            effort: None,
        }
    }
}

/// Request for a per-device undervolt shmoo campaign (`faults`): per virtual
/// unit, binary-search the lowest rails whose sampled fault population is
/// clean at every temperature corner, then convert the safe rails into a
/// measured sensor margin against the dynamic scheme's voltage LUT.
#[derive(Clone, Debug)]
pub struct ShmooRequest {
    pub bench: String,
    /// Virtual units to characterize; each draws its own process-variation
    /// threshold shift from the request seed.
    pub devices: usize,
    pub seed: u64,
    /// Temperature corner range (°C) — also the ambient range the voltage
    /// LUT is swept over.
    pub t_lo: f64,
    pub t_hi: f64,
    /// Ambient step of the LUT sweep (°C).
    pub lut_step_c: f64,
    /// Temperature corners probed per device (spread linearly over the
    /// range).
    pub corners: usize,
    /// Learned margins never drop below this (°C); it must stay at or above
    /// `sensor_error_c` so the zero-guardband-violation guarantee survives.
    pub margin_floor_c: f64,
    pub margin_max_c: f64,
    pub margin_step_c: f64,
    /// Worst-case sensor under-read (°C) assumed when converting safe rails
    /// into a margin.
    pub sensor_error_c: f64,
    /// Fault-population knobs shared by every probe.
    pub fault: FaultSpec,
    /// Campaign worker threads. Results are bit-identical for any count —
    /// the campaign keys every unit's work to its index and derived seeds.
    pub workers: usize,
    /// Monte-Carlo samples per accuracy-curve point.
    pub mc_samples: usize,
    pub theta_ja: Option<f64>,
    pub effort: Option<Effort>,
}

impl ShmooRequest {
    /// Defaults: 8 virtual units, 5 corners over 25–75 °C, margin search
    /// from the 3 °C floor in 0.25 °C steps against a 2 °C sensor error,
    /// one worker.
    ///
    /// # Examples
    ///
    /// ```
    /// use thermovolt::flow::ShmooRequest;
    ///
    /// let req = ShmooRequest { devices: 4, workers: 4, ..ShmooRequest::new("sha") };
    /// assert_eq!(req.corners, 5);
    /// assert!(req.margin_floor_c >= req.sensor_error_c);
    /// ```
    pub fn new(bench: impl Into<String>) -> ShmooRequest {
        ShmooRequest {
            bench: bench.into(),
            devices: 8,
            seed: 0xFA17_CA4B,
            t_lo: 25.0,
            t_hi: 75.0,
            lut_step_c: 10.0,
            corners: 5,
            margin_floor_c: 3.0,
            margin_max_c: 10.0,
            margin_step_c: 0.25,
            sensor_error_c: 2.0,
            fault: FaultSpec::default(),
            workers: 1,
            mc_samples: 400,
            theta_ja: None,
            effort: None,
        }
    }
}

/// Request for the online streaming fleet service (`fleet::stream`): open
/// Poisson arrivals with SLA deadlines and priorities, admission control
/// with queue shedding, and a rack autoscaler under a fleet-wide power
/// cap. One arrival stream per benchmark; every stream runs on its own
/// derived seed, and the whole run is bit-identical for any `workers`
/// count.
#[derive(Clone, Debug)]
pub struct StreamRequest {
    /// Primary benchmark stream.
    pub bench: String,
    /// Additional benchmark streams (each is its own independent arrival
    /// process; the fleet-wide rate splits evenly across all of them).
    pub extra_benches: Vec<String>,
    pub scenario: Scenario,
    pub racks: usize,
    pub devices_per_rack: usize,
    /// Arrival-generation window (virtual ms); admitted jobs then drain.
    pub horizon_ms: f64,
    /// Fleet-wide mean arrival rate (jobs/s).
    pub arrival_rate_hz: f64,
    /// Mean job duration (virtual ms; clamped exponential per job).
    pub duration_mean_ms: f64,
    /// SLA slack: deadline = arrival + slack × duration (≥ 1).
    pub deadline_slack: f64,
    /// Fleet power cap (W) the autoscaler must respect; 0 ⇒ uncapped.
    pub power_cap_w: f64,
    pub seed: u64,
    /// Data-plane worker threads (telemetry is bit-identical for any
    /// count — CI pins 1 vs 4 vs 8).
    pub workers: usize,
    /// Ambient step of the per-design LUT sweep (°C).
    pub lut_step_c: f64,
    /// Inter-rack thermal coupling (exhaust recirculation between
    /// neighbors); [`CouplingSpec::none`] disables it bit-exactly.
    pub coupling: CouplingSpec,
    /// Autoscaler predictive-ranking horizon (virtual ms); 0 keeps the
    /// legacy instantaneous rack ranking.
    pub lookahead_ms: f64,
    pub effort: Option<Effort>,
}

impl StreamRequest {
    /// Defaults: one `bench` stream into an 8 × 8 diurnal fleet, 1 job/s
    /// with 20 s mean service time, 2.5× deadline slack, no power cap,
    /// one data-plane worker over a 10-minute arrival window.
    ///
    /// # Examples
    ///
    /// ```
    /// use thermovolt::flow::StreamRequest;
    ///
    /// let req = StreamRequest { racks: 4, workers: 8, ..StreamRequest::new("sha") };
    /// assert_eq!(req.devices_per_rack, 8);
    /// assert!(req.deadline_slack >= 1.0); // deadline = arrival + slack × duration
    /// assert_eq!(req.power_cap_w, 0.0); // uncapped unless the caller says otherwise
    /// ```
    pub fn new(bench: impl Into<String>) -> StreamRequest {
        StreamRequest {
            bench: bench.into(),
            extra_benches: Vec::new(),
            scenario: Scenario::Diurnal,
            racks: 8,
            devices_per_rack: 8,
            horizon_ms: 600_000.0,
            arrival_rate_hz: 1.0,
            duration_mean_ms: 20_000.0,
            deadline_slack: 2.5,
            power_cap_w: 0.0,
            seed: 0x5742_EA5E,
            workers: 1,
            lut_step_c: 12.0,
            coupling: CouplingSpec::none(),
            lookahead_ms: 0.0,
            effort: None,
        }
    }

    /// The engine-facing [`StreamConfig`] this request resolves to.
    pub fn to_config(&self) -> StreamConfig {
        let mut benches = vec![self.bench.clone()];
        benches.extend(self.extra_benches.iter().cloned());
        StreamConfig {
            racks: self.racks,
            devices_per_rack: self.devices_per_rack,
            scenario: self.scenario,
            seed: self.seed,
            horizon_ms: self.horizon_ms,
            benches,
            arrival_rate_hz: self.arrival_rate_hz,
            duration_mean_ms: self.duration_mean_ms,
            deadline_slack: self.deadline_slack,
            power_cap_w: self.power_cap_w,
            lut_step_c: self.lut_step_c,
            coupling: self.coupling,
            lookahead_ms: self.lookahead_ms,
        }
    }
}

// ------------------------------------------------------------ outcomes --

/// Operating condition a request resolved to (base config + overrides) —
/// attached to every outcome so reports never re-derive it.
#[derive(Clone, Copy, Debug)]
pub struct Condition {
    pub t_amb_c: f64,
    pub theta_ja: f64,
    pub alpha: f64,
}

/// Outcome of [`FlowSession::alg1`] / [`FlowSession::baseline`].
#[derive(Clone, Debug)]
pub struct Alg1Outcome {
    pub bench: String,
    pub condition: Condition,
    pub result: Alg1Result,
}

/// Outcome of [`FlowSession::alg2`].
#[derive(Clone, Debug)]
pub struct Alg2Outcome {
    pub bench: String,
    pub condition: Condition,
    pub fidelity: Fidelity,
    pub result: Alg2Result,
}

/// Outcome of [`FlowSession::voltage_lut`].
#[derive(Clone, Debug)]
pub struct LutOutcome {
    pub bench: String,
    pub spec: LutSpec,
    pub lut: VoltageLut,
}

/// Outcome of [`FlowSession::transient`]: a power-step response of the
/// design's RC thermal network.
#[derive(Clone, Debug)]
pub struct TransientOutcome {
    pub bench: String,
    pub condition: Condition,
    /// Foster stages of the simulated network.
    pub stages: usize,
    /// Dominant time constant (ms).
    pub tau_ms: f64,
    /// Steady driving power (W): the design's nominal-rails thermal fixed
    /// point at the resolved condition.
    pub power_w: f64,
    /// Junction at t = 0 (°C) — the ambient.
    pub t_start_c: f64,
    /// Steady-state junction temperature (°C): `T_amb + θ_JA · P`, which a
    /// single-stage network reaches bit-identically to the lumped model.
    pub t_settle_c: f64,
    /// First time the rise crosses 63.2 % of its total (ms); `None` when
    /// the horizon ended before it did.
    pub t63_ms: Option<f64>,
    /// First time the rise crosses 95 % (ms); `None` if not reached.
    pub t95_ms: Option<f64>,
    /// Decimated `(t_ms, T_j °C)` trajectory (≈ ≤ 512 points + endpoints).
    pub samples: Vec<(f64, f64)>,
}

/// Outcome of [`FlowSession::overscale`].
#[derive(Clone, Debug)]
pub struct OverscaleOutcome {
    pub bench: String,
    pub condition: Condition,
    /// CP-delay violation budget the rails were optimized for.
    pub rate: f64,
    /// The Algorithm-1 solution under the relaxed constraint.
    pub alg1: Alg1Result,
    /// Per-endpoint timing-violation model at the converged (T, V).
    pub error: ErrorModel,
}

/// Outcome of [`FlowSession::shmoo`]: the per-unit campaign results, the
/// guardband store a fleet run can load in place of the fixed margin, and
/// accuracy-vs-rail curves for the critical-layer-protection experiment.
#[derive(Clone, Debug)]
pub struct ShmooOutcome {
    pub bench: String,
    pub condition: Condition,
    /// The fixed sensor margin the measured ones replace
    /// (`cfg.flow.sensor_margin` — the fleet's per-unit base).
    pub fixed_margin_c: f64,
    /// Per-unit learned guardbands (serialize via
    /// [`GuardbandStore::to_toml`]).
    pub store: GuardbandStore,
    /// Full per-unit shmoo traces, sorted by device id.
    pub results: Vec<ShmooResult>,
    /// Accuracy vs BRAM rail with no protection. The sweep extends below
    /// the voltage grid's floor — in-grid rails can sit entirely above the
    /// fault wall at cool corners, and the cliff is the point.
    pub accuracy: Vec<AccuracyPoint>,
    /// The same sweep with the deepest LeNet reduction layer protected
    /// (run at nominal rail via a dual-rail bank).
    pub accuracy_protected: Vec<AccuracyPoint>,
}

/// Outcome of [`FlowSession::stream`]: the streaming-service telemetry of
/// one seeded open-arrival run, plus the bit-exact fingerprint callers pin
/// across worker counts.
#[derive(Clone, Debug)]
pub struct StreamOutcome {
    /// Primary benchmark stream (the request may carry extra kinds).
    pub bench: String,
    pub condition: Condition,
    pub racks: usize,
    pub devices_per_rack: usize,
    /// Data-plane worker threads this run used (any count is identical).
    pub workers: usize,
    pub telemetry: StreamTelemetry,
    /// `telemetry.fingerprint()` — counters, energy, decisions, sketches.
    pub fingerprint: u64,
}

// ------------------------------------------------------------- session --

/// Per-design cached state: the placed design, its STA arena (arenas
/// intern per-device delay caches and must never cross designs), one
/// thermal backend per θ_JA actually requested, and the activity estimates
/// for every override-α actually requested (keyed by the α bit pattern —
/// `estimate` is a pure function of (netlist, α), so caching is
/// observationally invisible).
struct DesignEntry {
    design: Arc<Design>,
    arena: StaCacheArena,
    // detlint: allow(D001) keyed cache, get/entry only — iteration order never reaches a result
    backends: HashMap<u64, Box<dyn ThermalBackend>>,
    // detlint: allow(D001) keyed cache, get/entry only — iteration order never reaches a result
    acts: HashMap<u64, Arc<Activities>>,
    /// RC thermal networks keyed by (θ_JA bits, τ bits, stages) — like the
    /// per-θ backends, a pure function of the key, so caching is
    /// observationally invisible (requests clone and reset the template).
    // detlint: allow(D001) keyed cache, get/entry only — iteration order never reaches a result
    dynamics: HashMap<(u64, u64, usize), RcNetwork>,
}

/// The unified facade over every thermal-aware flow entry point. See the
/// module docs for the ownership/caching model.
pub struct FlowSession {
    cfg: Arc<Config>,
    effort: Effort,
    table: Arc<CharTable>,
    // detlint: allow(D001) keyed cache, get/entry only — iteration order never reaches a result
    designs: HashMap<(String, Effort), DesignEntry>,
}

impl FlowSession {
    /// Open a session over a validated base configuration, with
    /// [`Effort::Quick`] as the default placer effort.
    pub fn new(cfg: Config) -> Result<FlowSession, FlowError> {
        FlowSession::with_effort(cfg, Effort::Quick)
    }

    /// Open a session with an explicit default placer effort.
    pub fn with_effort(cfg: Config, effort: Effort) -> Result<FlowSession, FlowError> {
        validate_config(&cfg)?;
        Ok(FlowSession {
            cfg: Arc::new(cfg),
            effort,
            table: CharTable::shared(),
            // detlint: allow(D001) keyed cache, get/entry only
            designs: HashMap::new(),
        })
    }

    /// The session's base configuration.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// The session's default placer effort.
    pub fn effort(&self) -> Effort {
        self.effort
    }

    /// The process-wide characterized library the session's designs share.
    pub fn char_table(&self) -> &Arc<CharTable> {
        &self.table
    }

    /// The placed design for `bench` at the session's default effort,
    /// building (and caching) it on first use.
    pub fn design(&mut self, bench: &str) -> Result<Arc<Design>, FlowError> {
        self.design_at(bench, None)
    }

    /// [`design`](Self::design) with an explicit effort override.
    pub fn design_at(
        &mut self,
        bench: &str,
        effort: Option<Effort>,
    ) -> Result<Arc<Design>, FlowError> {
        let effort = effort.unwrap_or(self.effort);
        let entry = Self::entry(&mut self.designs, &self.cfg, bench, effort)?;
        Ok(entry.design.clone())
    }

    /// Cumulative STA-arena hit/miss counters for a cached design (`None`
    /// until the first request touches it). Counters only ever grow over a
    /// session's lifetime — the cache-reuse tests probe exactly that.
    pub fn arena_stats(&self, bench: &str, effort: Option<Effort>) -> Option<ArenaStats> {
        let effort = effort.unwrap_or(self.effort);
        self.designs
            .get(&(bench.to_string(), effort))
            .map(|e| e.arena.stats)
    }

    /// Number of designs the session has built and cached.
    pub fn cached_designs(&self) -> usize {
        self.designs.len()
    }

    /// Name of the thermal backend serving `bench` at the session's base
    /// condition (building design and backend on first use) — lets
    /// integration tests pin the PJRT AOT hot path without reaching into
    /// the session's internals.
    pub fn backend_name(&mut self, bench: &str) -> Result<&'static str, FlowError> {
        let cfg = self.resolved(None, None, None, None)?;
        let effort = self.effort;
        let (_design, _acts, _arena, backend) =
            Self::ctx(&mut self.designs, &self.cfg, &cfg, bench, effort, None)?;
        Ok(backend.name())
    }

    /// The memoized activity estimate for `bench` at `alpha` — the same
    /// object override-α requests price power with, so callers that need a
    /// custom power evaluation (e.g. fig7's energy re-pricing at α = 0.1)
    /// don't re-run the netlist estimate the session already holds.
    pub fn activities(&mut self, bench: &str, alpha: f64) -> Result<Arc<Activities>, FlowError> {
        let cfg = self.resolved(None, None, Some(alpha), None)?;
        let effort = self.effort;
        let (design, acts, _arena, _backend) =
            Self::ctx(&mut self.designs, &self.cfg, &cfg, bench, effort, Some(alpha))?;
        // alpha equal to the base config's: the design's own activities
        Ok(acts.unwrap_or_else(|| Arc::new(design.acts.clone())))
    }

    // ---------------------------------------------------------- flows --

    /// Algorithm 1 — thermal-aware voltage selection (§III-B), optionally
    /// with a §III-D CP-violation budget (`rate` > 1).
    pub fn alg1(&mut self, req: Alg1Request) -> Result<Alg1Outcome, FlowError> {
        validate_rate(req.rate)?;
        let cfg = self.resolved(req.ambient, req.theta_ja, req.alpha, None)?;
        let effort = req.effort.unwrap_or(self.effort);
        let (design, acts, arena, backend) =
            Self::ctx(&mut self.designs, &self.cfg, &cfg, &req.bench, effort, req.alpha)?;
        let sta = design.sta();
        let pm = match &acts {
            Some(a) => design.power_model_at(a),
            None => design.power_model(),
        };
        let result = alg1::run_impl(&design, &sta, &pm, &cfg, backend, req.rate, arena);
        Ok(Alg1Outcome {
            bench: req.bench,
            condition: condition_of(&cfg),
            result,
        })
    }

    /// The thermal fixed point at fixed rails: the nominal-rails baseline
    /// (the denominator of every "power reduction" number) or any explicit
    /// rails (the Fig. 4/6/7 activity-range re-evaluations).
    pub fn baseline(&mut self, req: BaselineRequest) -> Result<Alg1Outcome, FlowError> {
        if let Some((vc, vb)) = req.rails {
            for (name, v) in [("v_core", vc), ("v_bram", vb)] {
                if !v.is_finite() || v <= 0.0 {
                    return Err(FlowError::InvalidConfig {
                        field: "rails",
                        reason: format!("{name} = {v} V (must be finite and > 0)"),
                    });
                }
            }
        }
        let cfg = self.resolved(req.ambient, req.theta_ja, req.alpha, None)?;
        let effort = req.effort.unwrap_or(self.effort);
        let (design, acts, _arena, backend) =
            Self::ctx(&mut self.designs, &self.cfg, &cfg, &req.bench, effort, req.alpha)?;
        let sta = design.sta();
        let pm = match &acts {
            Some(a) => design.power_model_at(a),
            None => design.power_model(),
        };
        let (vc, vb) = req
            .rails
            .unwrap_or((cfg.arch.v_core_nom, cfg.arch.v_bram_nom));
        let result = alg1::fixed_point_impl(&design, &sta, &pm, &cfg, backend, vc, vb);
        Ok(Alg1Outcome {
            bench: req.bench,
            condition: condition_of(&cfg),
            result,
        })
    }

    /// Algorithm 2 — thermal-aware energy optimization (§III-C). The
    /// [`Fidelity`] knob selects the batched engine or the pre-refactor
    /// naive path (bit-identical results, different wall-clock).
    pub fn alg2(&mut self, req: Alg2Request) -> Result<Alg2Outcome, FlowError> {
        let cfg = self.resolved(req.ambient, req.theta_ja, req.alpha, req.prune)?;
        let effort = req.effort.unwrap_or(self.effort);
        let (design, acts, arena, backend) =
            Self::ctx(&mut self.designs, &self.cfg, &cfg, &req.bench, effort, req.alpha)?;
        let sta = design.sta();
        let pm = match &acts {
            Some(a) => design.power_model_at(a),
            None => design.power_model(),
        };
        let result = match req.fidelity {
            Fidelity::Fast => alg2::run_impl(&design, &sta, &pm, &cfg, backend, arena)?,
            // the naive path deliberately bypasses the arena — it is the
            // pre-refactor evaluation the bench times the engine against
            Fidelity::Naive => alg2::run_naive_impl(&design, &sta, &pm, &cfg, backend)?,
        };
        Ok(Alg2Outcome {
            bench: req.bench,
            condition: condition_of(&cfg),
            fidelity: req.fidelity,
            result,
        })
    }

    /// Paper-name alias for [`alg2`](Self::alg2) (§III-C calls the flow
    /// "thermal-aware energy optimization").
    pub fn energy_opt(&mut self, req: Alg2Request) -> Result<Alg2Outcome, FlowError> {
        self.alg2(req)
    }

    /// Build a (T → V) lookup table per the request's [`LutSpec`] —
    /// the safe ambient sweep, the §III-D over-scaled sweep, or the
    /// degenerate fixed-rails table.
    ///
    /// A sweep where *every* ambient point is infeasible returns
    /// [`FlowError::InfeasibleSweep`] rather than an empty table (an empty
    /// table silently falls back to nominal rails on every lookup).
    pub fn voltage_lut(&mut self, req: LutRequest) -> Result<LutOutcome, FlowError> {
        let sweep = match req.spec {
            LutSpec::Fixed { v_core, v_bram } => {
                for (name, v) in [("v_core", v_core), ("v_bram", v_bram)] {
                    if !v.is_finite() || v <= 0.0 {
                        return Err(FlowError::BadLutSpec {
                            reason: format!("fixed rail {name} = {v} V"),
                        });
                    }
                }
                return Ok(LutOutcome {
                    bench: req.bench,
                    spec: req.spec,
                    lut: VoltageLut::fixed_rails(v_core, v_bram),
                });
            }
            LutSpec::Sweep {
                t_amb_lo,
                t_amb_hi,
                step_c,
            } => LutSweep::validated(t_amb_lo, t_amb_hi, step_c, 1.0)?,
            LutSpec::SweepRate {
                t_amb_lo,
                t_amb_hi,
                step_c,
                rate,
            } => LutSweep::validated(t_amb_lo, t_amb_hi, step_c, rate)?,
        };
        let cfg = self.resolved(None, req.theta_ja, req.alpha, None)?;
        let effort = req.effort.unwrap_or(self.effort);
        let (design, acts, arena, backend) =
            Self::ctx(&mut self.designs, &self.cfg, &cfg, &req.bench, effort, req.alpha)?;
        let sta = design.sta();
        let pm = match &acts {
            Some(a) => design.power_model_at(a),
            None => design.power_model(),
        };
        let lut = dynamic::build_impl(&design, &sta, &pm, &cfg, backend, sweep, arena);
        if lut.entries.is_empty() {
            // every ambient point came back infeasible — surface the typed
            // error instead of handing back a table that silently falls
            // through to nominal rails on every lookup
            return Err(FlowError::InfeasibleSweep {
                bench: req.bench,
                t_amb_lo: sweep.t_amb_lo,
                t_amb_hi: sweep.t_amb_hi,
            });
        }
        Ok(LutOutcome {
            bench: req.bench,
            spec: req.spec,
            lut,
        })
    }

    /// The §III-D over-scaling flow: Algorithm 1 at the CP-violation
    /// budget, then the post-P&R timing simulation pricing every endpoint
    /// at the converged (T, V). Search and error model share the design's
    /// arena, so the error model reads caches the search already built.
    pub fn overscale(&mut self, req: OverscaleRequest) -> Result<OverscaleOutcome, FlowError> {
        validate_rate(req.rate)?;
        let cfg = self.resolved(req.ambient, req.theta_ja, req.alpha, None)?;
        let effort = req.effort.unwrap_or(self.effort);
        let (design, acts, arena, backend) =
            Self::ctx(&mut self.designs, &self.cfg, &cfg, &req.bench, effort, req.alpha)?;
        let sta = design.sta();
        let pm = match &acts {
            Some(a) => design.power_model_at(a),
            None => design.power_model(),
        };
        let alg1_result = alg1::run_impl(&design, &sta, &pm, &cfg, backend, req.rate, arena);
        let acts_ref: &Activities = acts.as_deref().unwrap_or(&design.acts);
        let error =
            overscale::error_model_impl(&design, acts_ref, &sta, &cfg, &alg1_result, arena);
        Ok(OverscaleOutcome {
            bench: req.bench,
            condition: condition_of(&cfg),
            rate: req.rate,
            alg1: alg1_result,
            error,
        })
    }

    /// RC thermal-network transient (`thermal::transient`): drive the
    /// design's nominal-rails fixed-point power as a step into a Foster
    /// network (per-request τ / stage count) and return the settling point,
    /// the 63.2 % / 95 % response times, and a decimated trajectory.
    ///
    /// The network for each `(θ_JA, τ, stages)` is cached on the design
    /// entry exactly like the per-θ thermal backends; a single-stage
    /// request settles **bit-identically** to the lumped `T_amb + θ_JA·P`
    /// steady state (the differential tests pin this).
    pub fn transient(&mut self, req: TransientRequest) -> Result<TransientOutcome, FlowError> {
        validate_transient(&req)?;
        let cfg = self.resolved(req.ambient, req.theta_ja, req.alpha, None)?;
        let effort = req.effort.unwrap_or(self.effort);
        let (design, acts, _arena, backend) =
            Self::ctx(&mut self.designs, &self.cfg, &cfg, &req.bench, effort, req.alpha)?;
        let sta = design.sta();
        let pm = match &acts {
            Some(a) => design.power_model_at(a),
            None => design.power_model(),
        };
        // the driving step: the nominal-rails thermal fixed point (the same
        // leg as `baseline`) gives the steady load the network is fed
        let fixed = alg1::fixed_point_impl(
            &design,
            &sta,
            &pm,
            &cfg,
            backend,
            cfg.arch.v_core_nom,
            cfg.arch.v_bram_nom,
        );
        let entry = self
            .designs
            .get_mut(&(req.bench.clone(), effort))
            // detlint: allow(D004) ctx() above inserted this exact key; a miss is a session bug
            .expect("ctx built this design entry");
        let mut net = entry
            .dynamics
            .entry((cfg.thermal.theta_ja.to_bits(), req.tau_ms.to_bits(), req.stages))
            .or_insert_with(|| {
                RcNetwork::foster(cfg.thermal.theta_ja, req.tau_ms, req.stages)
            })
            .clone();
        net.reset();

        let t_amb = cfg.flow.t_amb;
        let p = fixed.power;
        let t_settle = net.steady_state_c(p, t_amb);
        let rise_total = t_settle - t_amb;
        let n_steps = (req.horizon_ms / req.dt_ms).ceil() as usize;
        let stride = n_steps.div_ceil(512).max(1);
        let mut samples = vec![(0.0, t_amb)];
        let (mut t63, mut t95) = (None, None);
        let mut t_ms = 0.0;
        for i in 1..=n_steps {
            t_ms += req.dt_ms;
            let t = net.step(p, t_amb, req.dt_ms);
            if t63.is_none() && t - t_amb >= 0.632 * rise_total {
                t63 = Some(t_ms);
            }
            if t95.is_none() && t - t_amb >= 0.95 * rise_total {
                t95 = Some(t_ms);
            }
            if i % stride == 0 || i == n_steps {
                samples.push((t_ms, t));
            }
        }
        Ok(TransientOutcome {
            bench: req.bench,
            condition: condition_of(&cfg),
            stages: req.stages,
            tau_ms: req.tau_ms,
            power_w: p,
            t_start_c: t_amb,
            t_settle_c: t_settle,
            t63_ms: t63,
            t95_ms: t95,
            samples,
        })
    }

    /// Per-device undervolt shmoo campaign (`faults`): build the dynamic
    /// scheme's voltage LUT over the requested ambient range, fit the fault
    /// injector against the shared `chardb`, then — per virtual unit —
    /// binary-search the lowest sampled-clean rails at every temperature
    /// corner and convert them into a measured sensor margin. The outcome
    /// also carries accuracy-vs-rail curves (with and without critical-layer
    /// protection) from the same fitted models.
    ///
    /// Fully determined by `req.seed` and bit-identical for any `workers`
    /// count: every unit's threshold shift and probe stream derive from
    /// per-index seeds, never from a shared RNG.
    pub fn shmoo(&mut self, req: ShmooRequest) -> Result<ShmooOutcome, FlowError> {
        validate_shmoo(&req)?;
        req.fault
            .validate()
            .map_err(|reason| FlowError::BadFaultSpec { reason })?;
        let lut = self
            .voltage_lut(LutRequest {
                theta_ja: req.theta_ja,
                effort: req.effort,
                ..LutRequest::new(
                    req.bench.clone(),
                    LutSpec::Sweep {
                        t_amb_lo: req.t_lo,
                        t_amb_hi: req.t_hi,
                        step_c: req.lut_step_c,
                    },
                )
            })?
            .lut;
        let cfg = self.resolved(None, req.theta_ja, None, None)?;
        let design = self.design_at(&req.bench, req.effort)?;
        let map = BramMap::of_design(&design);
        let base = Injector::fit(&self.table, &cfg.vgrid, &cfg.arch, req.fault, 0.0);
        let sspec = faults::ShmooSpec {
            t_lo: req.t_lo,
            t_hi: req.t_hi,
            corners: req.corners,
            margin_floor_c: req.margin_floor_c,
            margin_max_c: req.margin_max_c,
            margin_step_c: req.margin_step_c,
            sensor_error_c: req.sensor_error_c,
            fault: req.fault,
        };
        let core_levels = cfg.vgrid.core_levels();
        let bram_levels = cfg.vgrid.bram_levels();
        let luts = vec![Arc::new(lut)];
        let units: Vec<(usize, f64)> = (0..req.devices)
            .map(|id| {
                let mut r = Xoshiro256::new(mix64(req.seed ^ faults::VTH_SEED_SALT, id as u64));
                (id, r.uniform(faults::VTH_SHIFT_LO, faults::VTH_SHIFT_HI))
            })
            .collect();
        let results = faults::campaign(&units, req.workers, |_, &(id, shift)| {
            faults::shmoo_device(
                &base.with_shift(shift),
                &map,
                &luts,
                &core_levels,
                &bram_levels,
                &sspec,
                id,
                mix64(req.seed ^ faults::SHMOO_SEED_SALT, id as u64),
            )
        });
        let store = GuardbandStore::from_results(&results);

        // accuracy-vs-rail at the mid corner on the nominal-threshold unit;
        // the sweep extends below the grid floor (in-grid rates can be
        // exactly zero at cool corners) so the cliff is visible
        let t_mid = 0.5 * (req.t_lo + req.t_hi);
        let mut acc_levels = Vec::new();
        let mut v = ACC_SWEEP_FLOOR_V;
        while v <= cfg.vgrid.v_bram_max + 1e-9 {
            acc_levels.push(v);
            v += ACC_SWEEP_STEP_V;
        }
        let clean = crate::fleet::policy::QUALITY_CLEAN_ACC;
        let chance = crate::fleet::policy::QUALITY_CHANCE_ACC;
        let deepest = (0..crate::ml::LENET_K.len())
            .max_by_key(|&l| crate::ml::LENET_K[l])
            .unwrap_or(0);
        let acc_seed = mix64(req.seed, 0xACC);
        let curve = |protect: Protection| {
            faults::accuracy_vs_rail(
                &base.bram,
                &acc_levels,
                t_mid,
                clean,
                chance,
                protect,
                cfg.arch.bram_bits,
                req.mc_samples,
                acc_seed,
            )
        };
        let accuracy = curve(Protection::None);
        let accuracy_protected = curve(Protection::Layer(deepest));
        Ok(ShmooOutcome {
            bench: req.bench,
            condition: condition_of(&cfg),
            fixed_margin_c: cfg.flow.sensor_margin,
            store,
            results,
            accuracy,
            accuracy_protected,
        })
    }

    /// Run the online streaming fleet service (`fleet::stream`): seeded
    /// open Poisson arrivals with SLA deadlines and priorities, admission
    /// control with queue shedding, and a rack autoscaler under the
    /// request's fleet-wide power cap.
    ///
    /// Validation runs before any design is built, so a bad spec costs
    /// nothing. Like the batch fleet, designs are priced at the scenario's
    /// deployment corner (θ_JA, base ambient) through a corner-adjusted
    /// inner session; the outcome's [`Condition`] reports that corner.
    ///
    /// Fully determined by `req.seed` and bit-identical for any `workers`
    /// count: the control plane (every admission/shed/scale decision) is
    /// serial, and the parallel data plane is a pure per-job function.
    pub fn stream(&mut self, req: StreamRequest) -> Result<StreamOutcome, FlowError> {
        if req.workers == 0 || req.workers > 64 {
            return Err(FlowError::BadStreamSpec {
                reason: format!("{} workers (must be 1..=64)", req.workers),
            });
        }
        let scfg = req.to_config();
        scfg.validate()?;
        let (t_base, theta) = req.scenario.corner();
        let cfg = self.resolved(Some(t_base), Some(theta), None, None)?;
        let mut inner = FlowSession::with_effort(cfg, req.effort.unwrap_or(self.effort))?;
        let sim = StreamSim::build(&mut inner, &scfg)?;
        let telemetry = sim.run(req.workers);
        Ok(StreamOutcome {
            bench: req.bench,
            condition: condition_of(inner.config()),
            racks: scfg.racks,
            devices_per_rack: scfg.devices_per_rack,
            workers: req.workers,
            fingerprint: telemetry.fingerprint(),
            telemetry,
        })
    }

    // ------------------------------------------------------- plumbing --

    /// Base config with per-request overrides applied, re-validated so a
    /// bad override is caught with the same typed error as a bad base.
    fn resolved(
        &self,
        ambient: Option<f64>,
        theta_ja: Option<f64>,
        alpha: Option<f64>,
        prune: Option<bool>,
    ) -> Result<Config, FlowError> {
        let mut cfg = (*self.cfg).clone();
        if let Some(t) = ambient {
            cfg.flow.t_amb = t;
        }
        if let Some(th) = theta_ja {
            cfg.thermal.theta_ja = th;
        }
        if let Some(a) = alpha {
            cfg.flow.alpha_in = a;
        }
        if let Some(p) = prune {
            cfg.flow.prune = p;
        }
        validate_config(&cfg)?;
        Ok(cfg)
    }

    /// The cached design entry for `(bench, effort)`, building the design
    /// on first use. Associated function (not `&mut self`) so callers can
    /// split borrows between the cache and the base config.
    fn entry<'s>(
        // detlint: allow(D001) keyed cache parameter, entry() access only
        designs: &'s mut HashMap<(String, Effort), DesignEntry>,
        base: &Config,
        bench: &str,
        effort: Effort,
    ) -> Result<&'s mut DesignEntry, FlowError> {
        match designs.entry((bench.to_string(), effort)) {
            Entry::Occupied(e) => Ok(e.into_mut()),
            Entry::Vacant(v) => {
                let design = build_design(bench, base, effort)?;
                Ok(v.insert(DesignEntry {
                    design: Arc::new(design),
                    arena: StaCacheArena::new(),
                    // detlint: allow(D001) keyed caches, get/entry only
                    backends: HashMap::new(),
                    // detlint: allow(D001) keyed caches, get/entry only
                    acts: HashMap::new(),
                    // detlint: allow(D001) keyed caches, get/entry only
                    dynamics: HashMap::new(),
                }))
            }
        }
    }

    /// Everything one request needs from the cache: the design, its
    /// activities for the request's α override (memoized per α — `None`
    /// means the design's own base-α activities apply), its arena, and the
    /// thermal backend for the resolved θ_JA (built on first use; both
    /// backends are stateless per solve, so reuse is bit-identical).
    fn ctx<'s>(
        // detlint: allow(D001) keyed cache parameter, forwarded to entry()
        designs: &'s mut HashMap<(String, Effort), DesignEntry>,
        base: &Config,
        cfg: &Config,
        bench: &str,
        effort: Effort,
        alpha: Option<f64>,
    ) -> Result<FlowCtx<'s>, FlowError> {
        let entry = Self::entry(designs, base, bench, effort)?;
        let design = entry.design.clone();
        // `resolved()` already rejected out-of-range α before any caller
        // reaches here (ctx is only entered with a validated request)
        debug_assert!(
            alpha.is_none_or(|a| a.is_finite() && a > 0.0 && a <= 1.0),
            "ctx called with unvalidated alpha"
        );
        let acts = match alpha {
            None => None,
            Some(a) if a == base.flow.alpha_in => None,
            Some(a) => Some(
                entry
                    .acts
                    .entry(a.to_bits())
                    .or_insert_with(|| Arc::new(design.activities_at(a)))
                    .clone(),
            ),
        };
        let backend = entry
            .backends
            .entry(cfg.thermal.theta_ja.to_bits())
            .or_insert_with(|| {
                select_backend(
                    &cfg.artifacts_dir,
                    design.dev.rows,
                    design.dev.cols,
                    &cfg.thermal,
                )
            });
        Ok((design, acts, &mut entry.arena, backend.as_mut()))
    }
}

/// The borrowed working set one request runs on: the cached design, the
/// memoized activities for the request's α override (if any), its STA
/// arena, and the thermal backend for the resolved θ_JA.
type FlowCtx<'s> = (
    Arc<Design>,
    Option<Arc<Activities>>,
    &'s mut StaCacheArena,
    &'s mut dyn ThermalBackend,
);

/// Resolve a benchmark name to a placed design: the VTR-profile suite by
/// name, plus the two ML accelerator profiles the over-scaling study uses.
fn build_design(bench: &str, cfg: &Config, effort: Effort) -> Result<Design, FlowError> {
    if let Some(profile) = crate::synth::benchmark(bench) {
        return Design::from_netlist(crate::synth::generate(profile), profile, cfg, effort);
    }
    let profile = match bench {
        "lenet_systolic" => crate::synth::lenet_accel(),
        "hd_engine" => crate::synth::hd_accel(),
        _ => {
            return Err(FlowError::UnknownBenchmark {
                name: bench.to_string(),
            })
        }
    };
    Design::from_netlist(crate::synth::generate(&profile), &profile, cfg, effort)
}

fn condition_of(cfg: &Config) -> Condition {
    Condition {
        t_amb_c: cfg.flow.t_amb,
        theta_ja: cfg.thermal.theta_ja,
        alpha: cfg.flow.alpha_in,
    }
}

fn validate_rate(rate: f64) -> Result<(), FlowError> {
    if !rate.is_finite() || rate < 1.0 {
        return Err(FlowError::InvalidRate { rate });
    }
    Ok(())
}

/// Cap on a transient simulation's step count (horizon / dt): far beyond
/// any legitimate sweep, but small enough that a typo'd `dt_ms` fails fast
/// instead of grinding for hours.
const MAX_TRANSIENT_STEPS: f64 = 2e6;

fn validate_transient(req: &TransientRequest) -> Result<(), FlowError> {
    for (name, v) in [
        ("tau_ms", req.tau_ms),
        ("dt_ms", req.dt_ms),
        ("horizon_ms", req.horizon_ms),
    ] {
        if !v.is_finite() || v <= 0.0 {
            return Err(FlowError::BadTransientSpec {
                reason: format!("{name} = {v} (must be finite and > 0)"),
            });
        }
    }
    if req.stages == 0 || req.stages > 8 {
        return Err(FlowError::BadTransientSpec {
            reason: format!("{} stages (must be 1..=8)", req.stages),
        });
    }
    let steps = req.horizon_ms / req.dt_ms;
    if steps > MAX_TRANSIENT_STEPS {
        return Err(FlowError::BadTransientSpec {
            reason: format!(
                "horizon/dt = {steps:.0} steps (cap {MAX_TRANSIENT_STEPS})"
            ),
        });
    }
    Ok(())
}

/// Accuracy-vs-rail sweeps start below the voltage grid's floor: the fault
/// wall at cool corners sits under `v_bram_min`, and the curve's entire
/// point is to cross it.
const ACC_SWEEP_FLOOR_V: f64 = 0.30;
const ACC_SWEEP_STEP_V: f64 = 0.025;

fn validate_shmoo(req: &ShmooRequest) -> Result<(), FlowError> {
    for (name, v) in [
        ("t_lo", req.t_lo),
        ("t_hi", req.t_hi),
        ("lut_step_c", req.lut_step_c),
        ("margin_floor_c", req.margin_floor_c),
        ("margin_max_c", req.margin_max_c),
        ("margin_step_c", req.margin_step_c),
        ("sensor_error_c", req.sensor_error_c),
    ] {
        if !v.is_finite() {
            return Err(FlowError::BadShmooSpec {
                reason: format!("{name} = {v} is not finite"),
            });
        }
    }
    if req.t_lo >= req.t_hi {
        return Err(FlowError::BadShmooSpec {
            reason: format!("t_lo {} >= t_hi {}", req.t_lo, req.t_hi),
        });
    }
    if req.lut_step_c <= 0.0 || req.margin_step_c <= 0.0 {
        return Err(FlowError::BadShmooSpec {
            reason: format!(
                "steps must be > 0 (lut_step_c {}, margin_step_c {})",
                req.lut_step_c, req.margin_step_c
            ),
        });
    }
    if req.sensor_error_c < 0.0 {
        return Err(FlowError::BadShmooSpec {
            reason: format!("sensor_error_c {} < 0", req.sensor_error_c),
        });
    }
    if req.margin_floor_c < req.sensor_error_c {
        return Err(FlowError::BadShmooSpec {
            reason: format!(
                "margin_floor_c {} below sensor_error_c {} — learned margins \
                 could no longer absorb a worst-case sensor under-read",
                req.margin_floor_c, req.sensor_error_c
            ),
        });
    }
    if req.margin_max_c < req.margin_floor_c {
        return Err(FlowError::BadShmooSpec {
            reason: format!(
                "margin_max_c {} < margin_floor_c {}",
                req.margin_max_c, req.margin_floor_c
            ),
        });
    }
    if req.devices == 0 || req.devices > 4096 {
        return Err(FlowError::BadShmooSpec {
            reason: format!("{} devices (must be 1..=4096)", req.devices),
        });
    }
    if req.corners == 0 || req.corners > 64 {
        return Err(FlowError::BadShmooSpec {
            reason: format!("{} corners (must be 1..=64)", req.corners),
        });
    }
    if req.workers == 0 || req.workers > 64 {
        return Err(FlowError::BadShmooSpec {
            reason: format!("{} workers (must be 1..=64)", req.workers),
        });
    }
    if req.mc_samples == 0 || req.mc_samples > 1_000_000 {
        return Err(FlowError::BadShmooSpec {
            reason: format!("{} mc_samples (must be 1..=1_000_000)", req.mc_samples),
        });
    }
    Ok(())
}

/// Reject configurations the flows cannot run on. The worst offender was
/// `voltage.step <= 0`, which made the grid constructor attempt a
/// usize::MAX-element axis; everything else either panicked deep in a flow
/// or silently produced NaN results.
pub(crate) fn validate_config(cfg: &Config) -> Result<(), FlowError> {
    let finite = |field: &'static str, v: f64| -> Result<(), FlowError> {
        if v.is_finite() {
            Ok(())
        } else {
            Err(FlowError::InvalidConfig {
                field,
                reason: format!("{v} is not finite"),
            })
        }
    };
    let positive = |field: &'static str, v: f64| -> Result<(), FlowError> {
        finite(field, v)?;
        if v > 0.0 {
            Ok(())
        } else {
            Err(FlowError::InvalidConfig {
                field,
                reason: format!("{v} must be > 0"),
            })
        }
    };
    positive("voltage.step", cfg.vgrid.step)?;
    positive("voltage.v_core_min", cfg.vgrid.v_core_min)?;
    positive("voltage.v_bram_min", cfg.vgrid.v_bram_min)?;
    finite("voltage.v_core_max", cfg.vgrid.v_core_max)?;
    finite("voltage.v_bram_max", cfg.vgrid.v_bram_max)?;
    for (field, lo, hi) in [
        (
            "voltage.v_core_min/max",
            cfg.vgrid.v_core_min,
            cfg.vgrid.v_core_max,
        ),
        (
            "voltage.v_bram_min/max",
            cfg.vgrid.v_bram_min,
            cfg.vgrid.v_bram_max,
        ),
    ] {
        if lo > hi {
            return Err(FlowError::InvalidConfig {
                field,
                reason: format!("min {lo} > max {hi}"),
            });
        }
    }
    positive("thermal.theta_ja", cfg.thermal.theta_ja)?;
    positive("thermal.delta_t", cfg.thermal.delta_t)?;
    finite("flow.t_amb", cfg.flow.t_amb)?;
    finite("flow.guardband", cfg.flow.guardband)?;
    if cfg.flow.guardband < 0.0 {
        return Err(FlowError::InvalidConfig {
            field: "flow.guardband",
            reason: format!("{} must be >= 0", cfg.flow.guardband),
        });
    }
    if !(0.0..=1.0).contains(&cfg.flow.alpha_in) || cfg.flow.alpha_in == 0.0 {
        return Err(FlowError::InvalidConfig {
            field: "flow.alpha_in",
            reason: format!("activity {} (must be in (0, 1])", cfg.flow.alpha_in),
        });
    }
    if cfg.flow.max_iters == 0 {
        return Err(FlowError::InvalidConfig {
            field: "flow.max_iters",
            reason: "must be >= 1".to_string(),
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bad_configs_are_rejected_with_typed_errors() {
        let mut cfg = Config::new();
        cfg.vgrid.step = 0.0;
        // pre-session this OOM'd building a usize::MAX-element voltage axis
        match FlowSession::new(cfg).err() {
            Some(FlowError::InvalidConfig { field, .. }) => {
                assert_eq!(field, "voltage.step")
            }
            other => panic!("expected InvalidConfig, got {other:?}"),
        }

        let mut cfg = Config::new();
        cfg.thermal.theta_ja = -1.0;
        assert!(matches!(
            FlowSession::new(cfg),
            Err(FlowError::InvalidConfig {
                field: "thermal.theta_ja",
                ..
            })
        ));

        let mut cfg = Config::new();
        cfg.vgrid.v_core_min = 0.9;
        cfg.vgrid.v_core_max = 0.6;
        assert!(matches!(
            FlowSession::new(cfg),
            Err(FlowError::InvalidConfig { .. })
        ));
    }

    #[test]
    fn unknown_benchmark_is_a_typed_error() {
        let mut s = FlowSession::new(Config::new()).unwrap();
        match s.alg1(Alg1Request::new("definitely-not-a-benchmark")) {
            Err(FlowError::UnknownBenchmark { name }) => {
                assert_eq!(name, "definitely-not-a-benchmark")
            }
            other => panic!("expected UnknownBenchmark, got {other:?}"),
        }
    }

    #[test]
    fn invalid_rates_and_lut_specs_are_rejected() {
        let mut s = FlowSession::new(Config::new()).unwrap();
        assert!(matches!(
            s.alg1(Alg1Request {
                rate: 0.8,
                ..Alg1Request::new("mkPktMerge")
            }),
            Err(FlowError::InvalidRate { .. })
        ));
        assert!(matches!(
            s.overscale(OverscaleRequest::new("mkPktMerge", f64::NAN)),
            Err(FlowError::InvalidRate { .. })
        ));
        // a zero ambient step hung the legacy sweep forever
        assert!(matches!(
            s.voltage_lut(LutRequest::new(
                "mkPktMerge",
                LutSpec::Sweep {
                    t_amb_lo: 0.0,
                    t_amb_hi: 80.0,
                    step_c: 0.0
                }
            )),
            Err(FlowError::BadLutSpec { .. })
        ));
        // inverted bounds
        assert!(matches!(
            s.voltage_lut(LutRequest::new(
                "mkPktMerge",
                LutSpec::Sweep {
                    t_amb_lo: 60.0,
                    t_amb_hi: 10.0,
                    step_c: 5.0
                }
            )),
            Err(FlowError::BadLutSpec { .. })
        ));
        // none of the rejections should have paid for a design build
        assert_eq!(s.cached_designs(), 0);
    }

    #[test]
    fn bad_shmoo_and_fault_specs_are_rejected_before_any_build() {
        let mut s = FlowSession::new(Config::new()).unwrap();
        assert!(matches!(
            s.shmoo(ShmooRequest {
                t_lo: 80.0,
                t_hi: 25.0,
                ..ShmooRequest::new("mkPktMerge")
            }),
            Err(FlowError::BadShmooSpec { .. })
        ));
        // a floor below the sensor error would break the zero-violation
        // guarantee the learned margins must keep
        assert!(matches!(
            s.shmoo(ShmooRequest {
                margin_floor_c: 1.0,
                ..ShmooRequest::new("mkPktMerge")
            }),
            Err(FlowError::BadShmooSpec { .. })
        ));
        assert!(matches!(
            s.shmoo(ShmooRequest {
                devices: 0,
                ..ShmooRequest::new("mkPktMerge")
            }),
            Err(FlowError::BadShmooSpec { .. })
        ));
        assert!(matches!(
            s.shmoo(ShmooRequest {
                workers: 0,
                ..ShmooRequest::new("mkPktMerge")
            }),
            Err(FlowError::BadShmooSpec { .. })
        ));
        let mut bad_fault = ShmooRequest::new("mkPktMerge");
        bad_fault.fault.samples = 0;
        assert!(matches!(
            s.shmoo(bad_fault),
            Err(FlowError::BadFaultSpec { .. })
        ));
        // none of the rejections paid for a design build
        assert_eq!(s.cached_designs(), 0);
    }

    #[test]
    fn bad_stream_specs_are_rejected_before_any_build() {
        let mut s = FlowSession::new(Config::new()).unwrap();
        assert!(matches!(
            s.stream(StreamRequest {
                racks: 0,
                ..StreamRequest::new("mkPktMerge")
            }),
            Err(FlowError::BadStreamSpec { .. })
        ));
        assert!(matches!(
            s.stream(StreamRequest {
                workers: 0,
                ..StreamRequest::new("mkPktMerge")
            }),
            Err(FlowError::BadStreamSpec { .. })
        ));
        // a slack below 1 would make every admitted job a violation by
        // construction — reject it as a spec error instead
        assert!(matches!(
            s.stream(StreamRequest {
                deadline_slack: 0.5,
                ..StreamRequest::new("mkPktMerge")
            }),
            Err(FlowError::BadStreamSpec { .. })
        ));
        assert!(matches!(
            s.stream(StreamRequest {
                arrival_rate_hz: f64::NAN,
                ..StreamRequest::new("mkPktMerge")
            }),
            Err(FlowError::BadStreamSpec { .. })
        ));
        // an open stream of ~10^9 jobs is a typo, not a workload
        assert!(matches!(
            s.stream(StreamRequest {
                arrival_rate_hz: 1e6,
                horizon_ms: 1e9,
                ..StreamRequest::new("mkPktMerge")
            }),
            Err(FlowError::BadStreamSpec { .. })
        ));
        // an exhaust fraction of 1 has no bounded mutual-heating fixed
        // point — rejected with the coupling-specific typed error
        assert!(matches!(
            s.stream(StreamRequest {
                coupling: CouplingSpec {
                    exhaust_fraction: 1.0,
                    ..CouplingSpec::rack(0.2)
                },
                ..StreamRequest::new("mkPktMerge")
            }),
            Err(FlowError::BadCouplingSpec { .. })
        ));
        // a negative lookahead horizon is a stream-spec error
        assert!(matches!(
            s.stream(StreamRequest {
                lookahead_ms: -1.0,
                ..StreamRequest::new("mkPktMerge")
            }),
            Err(FlowError::BadStreamSpec { .. })
        ));
        // none of the rejections paid for a design build
        assert_eq!(s.cached_designs(), 0);
    }

    #[test]
    fn all_infeasible_sweep_is_a_typed_error() {
        // pin both rails to the 0.55 V floor: mkPktMerge's BRAM-critical
        // path can never meet the nominal-rail d_worst there, so every
        // ambient point of the sweep comes back infeasible and the session
        // must report InfeasibleSweep instead of an empty (silently
        // nominal-falling-back) table
        let mut cfg = Config::new();
        cfg.thermal.theta_ja = 12.0;
        cfg.vgrid.v_core_min = 0.55;
        cfg.vgrid.v_core_max = 0.55;
        cfg.vgrid.v_bram_min = 0.55;
        cfg.vgrid.v_bram_max = 0.55;
        let mut s = FlowSession::new(cfg).unwrap();
        match s.voltage_lut(LutRequest::new(
            "mkPktMerge",
            LutSpec::Sweep {
                t_amb_lo: 20.0,
                t_amb_hi: 60.0,
                step_c: 20.0,
            },
        )) {
            Err(FlowError::InfeasibleSweep {
                bench,
                t_amb_lo,
                t_amb_hi,
            }) => {
                assert_eq!(bench, "mkPktMerge");
                assert_eq!(t_amb_lo, 20.0);
                assert_eq!(t_amb_hi, 60.0);
            }
            Ok(out) => panic!(
                "expected InfeasibleSweep, got a table with {} entries",
                out.lut.entries.len()
            ),
            Err(other) => panic!("expected InfeasibleSweep, got {other:?}"),
        }
    }

    #[test]
    fn fixed_lut_spec_needs_no_design_build() {
        let mut s = FlowSession::new(Config::new()).unwrap();
        let out = s
            .voltage_lut(LutRequest::new(
                "mkPktMerge",
                LutSpec::Fixed {
                    v_core: 0.72,
                    v_bram: 0.88,
                },
            ))
            .unwrap();
        assert_eq!(out.lut.lookup(55.0, 5.0), (0.72, 0.88));
        assert_eq!(s.cached_designs(), 0, "Fixed spec must not build a design");
        assert!(matches!(
            s.voltage_lut(LutRequest::new(
                "x",
                LutSpec::Fixed {
                    v_core: -0.1,
                    v_bram: 0.9
                }
            )),
            Err(FlowError::BadLutSpec { .. })
        ));
    }

    #[test]
    fn bad_transient_specs_are_typed_errors_without_a_design_build() {
        let mut s = FlowSession::new(Config::new()).unwrap();
        for req in [
            TransientRequest {
                tau_ms: 0.0,
                ..TransientRequest::new("mkPktMerge")
            },
            TransientRequest {
                dt_ms: -1.0,
                ..TransientRequest::new("mkPktMerge")
            },
            TransientRequest {
                stages: 0,
                ..TransientRequest::new("mkPktMerge")
            },
            TransientRequest {
                stages: 99,
                ..TransientRequest::new("mkPktMerge")
            },
            TransientRequest {
                dt_ms: 1e-6,
                horizon_ms: 1e9,
                ..TransientRequest::new("mkPktMerge")
            },
        ] {
            assert!(
                matches!(s.transient(req.clone()), Err(FlowError::BadTransientSpec { .. })),
                "accepted bad spec {req:?}"
            );
        }
        assert_eq!(s.cached_designs(), 0, "rejections must not pay for P&R");
    }

    #[test]
    fn transient_settles_to_the_lumped_steady_state_and_caches_the_design() {
        let mut cfg = Config::new();
        cfg.thermal.theta_ja = 12.0;
        let mut s = FlowSession::new(cfg).unwrap();
        let out = s
            .transient(TransientRequest {
                stages: 1,
                tau_ms: 3000.0,
                dt_ms: 50.0,
                horizon_ms: 40_000.0,
                ..TransientRequest::new("mkPktMerge")
            })
            .unwrap();
        // single stage ⇒ the settle point is exactly T_amb + θ_JA·P
        let lumped = out.condition.t_amb_c + out.condition.theta_ja * out.power_w;
        assert!(
            (out.t_settle_c - lumped).abs() < 1e-9,
            "settle {} vs lumped {lumped}",
            out.t_settle_c
        );
        // the 63.2 % crossing of a single pole sits at τ (within one dt)
        let t63 = out.t63_ms.expect("40 s horizon covers 3 s pole");
        assert!(
            (t63 - 3000.0).abs() <= 50.0 + 1e-9,
            "t63 {t63} ms away from τ"
        );
        let t95 = out.t95_ms.unwrap();
        assert!(t95 > t63);
        // trajectory is decimated, monotone, and ends near settle
        assert!(out.samples.len() <= 514);
        assert!(out.samples.windows(2).all(|w| w[1].1 >= w[0].1 - 1e-12));
        let last = out.samples.last().unwrap().1;
        assert!((last - out.t_settle_c).abs() < 0.01);
        // the transient request cached the design like any other flow
        assert_eq!(s.cached_designs(), 1);
        let again = s
            .transient(TransientRequest {
                stages: 1,
                ..TransientRequest::new("mkPktMerge")
            })
            .unwrap();
        assert_eq!(s.cached_designs(), 1);
        assert_eq!(again.power_w.to_bits(), out.power_w.to_bits());
    }

    #[test]
    fn design_cache_is_keyed_by_bench_and_effort() {
        let mut s = FlowSession::new(Config::new()).unwrap();
        let a = s.design("mkPktMerge").unwrap();
        let b = s.design("mkPktMerge").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same key must reuse the design");
        assert_eq!(s.cached_designs(), 1);
        let c = s.design_at("mkPktMerge", Some(Effort::Quick)).unwrap();
        assert!(Arc::ptr_eq(&a, &c), "explicit default effort is the same key");
    }
}
