//! Dynamic (online) implementation support (§III-B "Static and Dynamic
//! Implementations"): at configuration time, build a lookup table keyed by
//! junction temperature whose values are the power-optimal (V_core, V_bram)
//! for that temperature; at run time the thermal-sensor-driven controller
//! (`crate::coordinator`) indexes it directly (the sensed temperature acts
//! as the VID for the on-chip regulator [39]).

use crate::config::Config;
use crate::flow::alg1;
use crate::flow::design::Design;
use crate::flow::error::FlowError;
use crate::power::PowerModel;
use crate::thermal::ThermalBackend;
use crate::timing::{Sta, StaCacheArena};

/// Validated parameters of a (T → V) LUT ambient sweep — the internal form
/// `FlowSession::voltage_lut` lowers its `LutSpec` into.
#[derive(Clone, Copy, Debug)]
pub(crate) struct LutSweep {
    pub t_amb_lo: f64,
    pub t_amb_hi: f64,
    pub step_c: f64,
    /// §III-D CP-violation budget (1.0 = the safe table).
    pub rate: f64,
}

impl LutSweep {
    /// Reject sweeps that cannot terminate or cannot produce a table. The
    /// legacy `VoltageLut::build` looped forever on `step <= 0`.
    pub(crate) fn validated(
        t_amb_lo: f64,
        t_amb_hi: f64,
        step_c: f64,
        rate: f64,
    ) -> Result<LutSweep, FlowError> {
        if !step_c.is_finite() || step_c <= 0.0 {
            return Err(FlowError::BadLutSpec {
                reason: format!("ambient step {step_c} °C (must be finite and > 0)"),
            });
        }
        if !t_amb_lo.is_finite() || !t_amb_hi.is_finite() || t_amb_lo > t_amb_hi {
            return Err(FlowError::BadLutSpec {
                reason: format!("ambient range [{t_amb_lo}, {t_amb_hi}] °C"),
            });
        }
        if !rate.is_finite() || rate < 1.0 {
            return Err(FlowError::InvalidRate { rate });
        }
        Ok(LutSweep {
            t_amb_lo,
            t_amb_hi,
            step_c,
            rate,
        })
    }
}

/// One LUT row: junction temperature key → optimal rails.
#[derive(Clone, Copy, Debug)]
pub struct LutEntry {
    /// Junction-temperature key (°C): valid while T_j ≤ this key.
    pub t_junct: f64,
    pub v_core: f64,
    pub v_bram: f64,
    /// Expected total power at this operating point (W).
    pub power: f64,
}

/// The per-design voltage lookup table.
#[derive(Clone, Debug)]
pub struct VoltageLut {
    pub entries: Vec<LutEntry>,
    /// Fallback = nominal rails (beyond the characterized range).
    pub v_core_nom: f64,
    pub v_bram_nom: f64,
}

impl VoltageLut {
    /// Build by sweeping ambient temperature and recording the converged
    /// junction temperature of each Algorithm-1 solution.
    #[deprecated(note = "construct flows through `flow::FlowSession::voltage_lut` with `LutSpec::Sweep`")]
    pub fn build(
        design: &Design,
        cfg: &Config,
        backend: &mut dyn ThermalBackend,
        t_amb_lo: f64,
        t_amb_hi: f64,
        step: f64,
    ) -> VoltageLut {
        // bit-identity contract: inverted (or NaN) bounds made the legacy
        // while loop run zero times — keep returning the empty table here
        if t_amb_lo.is_nan() || t_amb_hi.is_nan() || t_amb_lo > t_amb_hi {
            return VoltageLut {
                entries: Vec::new(),
                v_core_nom: cfg.arch.v_core_nom,
                v_bram_nom: cfg.arch.v_bram_nom,
            };
        }
        let sweep = match LutSweep::validated(t_amb_lo, t_amb_hi, step, 1.0) {
            Ok(s) => s,
            // the legacy signature is infallible: a spec the typed API
            // rejects panics here (a zero step used to hang the sweep
            // forever; infinite bounds never terminated either)
            Err(e) => panic!("{e}"),
        };
        let sta = design.sta();
        let pm = design.power_model();
        let mut arena = StaCacheArena::new();
        build_impl(design, &sta, &pm, cfg, backend, sweep, &mut arena)
    }

    /// [`build`](Self::build) with the timing constraint relaxed to
    /// `rate × d_worst` (§III-D over-scaling): each ambient's Algorithm-1
    /// run accepts the given CP-violation budget, so the recorded rails sit
    /// below the safe table's — the fleet's overscaled-dynamic policy
    /// drives its controller off this table.
    #[deprecated(note = "construct flows through `flow::FlowSession::voltage_lut` with `LutSpec::SweepRate`")]
    pub fn build_rate(
        design: &Design,
        cfg: &Config,
        backend: &mut dyn ThermalBackend,
        t_amb_lo: f64,
        t_amb_hi: f64,
        step: f64,
        rate: f64,
    ) -> VoltageLut {
        // see `build`: inverted/NaN bounds legacy-return an empty table
        if t_amb_lo.is_nan() || t_amb_hi.is_nan() || t_amb_lo > t_amb_hi {
            return VoltageLut {
                entries: Vec::new(),
                v_core_nom: cfg.arch.v_core_nom,
                v_bram_nom: cfg.arch.v_bram_nom,
            };
        }
        let sweep = match LutSweep::validated(t_amb_lo, t_amb_hi, step, rate) {
            Ok(s) => s,
            Err(e) => panic!("{e}"),
        };
        let sta = design.sta();
        let pm = design.power_model();
        let mut arena = StaCacheArena::new();
        build_impl(design, &sta, &pm, cfg, backend, sweep, &mut arena)
    }

    /// Degenerate single-row LUT that always commands the given rails —
    /// the static scheme expressed as a controller input, so the fleet
    /// simulator can run static and dynamic policies through the identical
    /// plant model.
    #[deprecated(note = "construct flows through `flow::FlowSession::voltage_lut` with `LutSpec::Fixed`")]
    pub fn fixed(v_core: f64, v_bram: f64) -> VoltageLut {
        Self::fixed_rails(v_core, v_bram)
    }

    /// Crate-internal form of the degenerate fixed-rails table (the policy
    /// engine's static leg runs the plant off one of these every job).
    pub(crate) fn fixed_rails(v_core: f64, v_bram: f64) -> VoltageLut {
        VoltageLut {
            entries: vec![LutEntry {
                t_junct: f64::MAX,
                v_core,
                v_bram,
                power: 0.0,
            }],
            v_core_nom: v_core,
            v_bram_nom: v_bram,
        }
    }

    /// Look up the rails for a sensed junction temperature, applying the
    /// sensor margin (TSD error + spatial gradients, ~5 °C).
    ///
    /// Binary search for the first entry with `t_junct >= key` — the same
    /// row the old linear scan returned, bit-identically, but O(log n):
    /// this runs on every 1 ms controller tick of every device in the
    /// fleet. `partition_point` needs the entries sorted by `t_junct`;
    /// `build_rate` establishes that invariant (and debug-asserts it once
    /// at construction — not here, where it would be an O(n) scan per
    /// tick), and hand-built tables must uphold it themselves.
    pub fn lookup(&self, t_sensed: f64, margin: f64) -> (f64, f64) {
        let key = t_sensed + margin;
        let i = self.entries.partition_point(|e| e.t_junct < key);
        match self.entries.get(i) {
            Some(e) => (e.v_core, e.v_bram),
            // beyond the characterized range (or an empty/degenerate LUT):
            // fall back to the safe nominal rails
            None => (self.v_core_nom, self.v_bram_nom),
        }
    }
}

/// The validated ambient sweep behind `FlowSession::voltage_lut`: one
/// Algorithm-1 run per ambient point, all sharing the caller's
/// [`StaCacheArena`] (the `d_worst` STA at (T_max, V_nom) and every delay
/// cache whose (V, T-map) condition recurs across ambients are computed
/// once).
pub(crate) fn build_impl(
    design: &Design,
    sta: &Sta<'_>,
    pm: &PowerModel<'_>,
    cfg: &Config,
    backend: &mut dyn ThermalBackend,
    sweep: LutSweep,
    arena: &mut StaCacheArena,
) -> VoltageLut {
    let mut entries = Vec::new();
    let mut t = sweep.t_amb_lo;
    while t <= sweep.t_amb_hi + 1e-9 {
        let mut c = cfg.clone();
        c.flow.t_amb = t;
        let r = alg1::run_impl(design, sta, pm, &c, backend, sweep.rate, arena);
        if !r.infeasible {
            entries.push(LutEntry {
                t_junct: crate::util::stats::max(&r.temp),
                v_core: r.v_core,
                v_bram: r.v_bram,
                power: r.power,
            });
        }
        t += sweep.step_c;
    }
    entries.sort_by(|a, b| a.t_junct.total_cmp(&b.t_junct));
    // Safety envelope: Algorithm 1 may trade the rails non-monotonically
    // across temperature (Fig. 4a). A sensed temperature between two keys
    // must never command less than any cooler key requires, so both rails
    // are made non-decreasing in T (conservative: a few mV of the
    // cross-rail trade is given up for guaranteed timing).
    let mut vc_run: f64 = 0.0;
    let mut vb_run: f64 = 0.0;
    for e in entries.iter_mut() {
        vc_run = vc_run.max(e.v_core);
        vb_run = vb_run.max(e.v_bram);
        e.v_core = vc_run;
        e.v_bram = vb_run;
    }
    // `lookup` binary-searches on t_junct; the sort above established
    // the invariant, checked once here rather than on every 1 ms tick
    debug_assert!(
        entries.windows(2).all(|w| w[0].t_junct <= w[1].t_junct),
        "VoltageLut entries not sorted by t_junct"
    );
    VoltageLut {
        entries,
        v_core_nom: cfg.arch.v_core_nom,
        v_bram_nom: cfg.arch.v_bram_nom,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::design::Effort;
    use crate::thermal::{NativeSolver, ThermalGrid};

    /// The pre-refactor linear scan, kept as the reference for bit-identity.
    fn lookup_linear(lut: &VoltageLut, t_sensed: f64, margin: f64) -> (f64, f64) {
        let key = t_sensed + margin;
        for e in &lut.entries {
            if key <= e.t_junct {
                return (e.v_core, e.v_bram);
            }
        }
        (lut.v_core_nom, lut.v_bram_nom)
    }

    #[test]
    fn binary_search_lookup_matches_linear_scan_bit_for_bit() {
        let mut rng = crate::util::Xoshiro256::new(0x100C_0B5E);
        for n in [0usize, 1, 2, 3, 7, 19] {
            // random sorted keys, including duplicates
            let mut keys: Vec<f64> = (0..n).map(|_| rng.uniform(20.0, 100.0)).collect();
            if n > 2 {
                keys[1] = keys[0]; // duplicate key
            }
            keys.sort_by(|a, b| a.total_cmp(b));
            let lut = VoltageLut {
                entries: keys
                    .iter()
                    .enumerate()
                    .map(|(i, &t)| LutEntry {
                        t_junct: t,
                        v_core: 0.60 + 0.01 * i as f64,
                        v_bram: 0.75 + 0.01 * i as f64,
                        power: 0.3,
                    })
                    .collect(),
                v_core_nom: 0.80,
                v_bram_nom: 0.95,
            };
            for _ in 0..400 {
                let t = rng.uniform(-10.0, 130.0);
                let m = rng.uniform(0.0, 8.0);
                let a = lut.lookup(t, m);
                let b = lookup_linear(&lut, t, m);
                assert_eq!(a.0.to_bits(), b.0.to_bits(), "n={n} t={t} m={m}");
                assert_eq!(a.1.to_bits(), b.1.to_bits(), "n={n} t={t} m={m}");
            }
            // exact-key probes (the partition boundary itself)
            for &k in &keys {
                let a = lut.lookup(k, 0.0);
                let b = lookup_linear(&lut, k, 0.0);
                assert_eq!(a, b, "boundary at {k}");
            }
        }
    }

    #[test]
    fn empty_and_degenerate_luts_fall_back_to_nominal() {
        let empty = VoltageLut {
            entries: vec![],
            v_core_nom: 0.80,
            v_bram_nom: 0.95,
        };
        assert_eq!(empty.lookup(45.0, 5.0), (0.80, 0.95));
        // the fixed (static-policy) LUT answers its rails at any temperature
        let fixed = VoltageLut::fixed_rails(0.72, 0.88);
        assert_eq!(fixed.lookup(-40.0, 0.0), (0.72, 0.88));
        assert_eq!(fixed.lookup(300.0, 10.0), (0.72, 0.88));
    }

    #[test]
    fn lut_is_monotone_and_conservative() {
        let mut cfg = Config::new();
        cfg.thermal.theta_ja = 12.0;
        let d = Design::build("mkPktMerge", &cfg, Effort::Quick).unwrap();
        let mut solver = NativeSolver::new(
            ThermalGrid::calibrated(d.dev.rows, d.dev.cols, &cfg.thermal),
            &cfg.thermal,
        );
        let sta = d.sta();
        let pm = d.power_model();
        let mut arena = StaCacheArena::new();
        let sweep = LutSweep::validated(10.0, 70.0, 20.0, 1.0).unwrap();
        let lut = build_impl(&d, &sta, &pm, &cfg, &mut solver, sweep, &mut arena);
        assert!(lut.entries.len() >= 3);
        // safety envelope: hotter keys never have lower voltage on EITHER
        // rail (lookup conservativeness for the online controller)
        for w in lut.entries.windows(2) {
            assert!(w[1].v_core + 1e-12 >= w[0].v_core);
            assert!(w[1].v_bram + 1e-12 >= w[0].v_bram);
        }
        // lookup picks the first key ≥ sensed+margin; far beyond ⇒ nominal
        let (vc, _) = lut.lookup(200.0, 5.0);
        assert_eq!(vc, lut.v_core_nom);
        let (vc_cool, _) = lut.lookup(lut.entries[0].t_junct - 10.0, 5.0);
        assert!(vc_cool <= lut.entries[1].v_core + 1e-12);
    }
}
