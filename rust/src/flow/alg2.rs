//! Algorithm 2 — Thermal-Aware Energy Optimization (§III-C).
//!
//! For every (V_core, V_bram) pair: iterate { d_max ← STA(T, V); P ←
//! P_lkg(T, V) + P_dyn(α, d_max, V); T ← HotSpot(P) } to the temperature
//! fixed point; energy = d_max × ΣP (power-delay product — Eq. (1) shows
//! running at max frequency for a given voltage is always energy-optimal
//! because leakage energy scales with the period). Return the pair with
//! minimum energy.
//!
//! The paper's two search optimizations (two-orders-of-magnitude speedup,
//! 72 min → 49 s) are reproduced:
//! 1. *energy pruning* — skip a pair whose initial-loop energy (T = T_amb,
//!    before the temperature-delay feedback) already exceeds the best found
//!    (feedback only increases T, hence delay and leakage, hence energy);
//! 2. *thermal memoization* — if a candidate's power is within
//!    `0.1 / θ_JA` of a previously simulated case, reuse that case's
//!    converged temperature map instead of re-running the thermal solver.
//!
//! On top of those, the default path runs on the batched, memoizing STA
//! engine (`timing::batch`): the whole voltage grid's initial pricing is one
//! [`Sta::analyze_flat_many`] pass + one prepared-power sweep, and the
//! feedback loop's per-tile STAs go through a [`StaCacheArena`] so delay
//! caches are shared wherever the thermal memo collapses temperature maps.
//! [`run_naive_with`] preserves the pre-refactor per-probe path; results are
//! bit-identical (asserted by `tests/batch_sta.rs` and `thermovolt bench`).

use crate::config::Config;
use crate::flow::design::Design;
use crate::flow::error::FlowError;
use crate::power::PowerModel;
use crate::thermal::ThermalBackend;
use crate::timing::{Sta, StaCacheArena};

#[derive(Clone, Debug)]
pub struct Alg2Result {
    pub v_core: f64,
    pub v_bram: f64,
    /// Optimal operating clock period (seconds, guardbanded).
    pub period: f64,
    /// Energy rate at the optimum: power × period (J per cycle).
    pub energy: f64,
    /// Total power at the optimum (W).
    pub power: f64,
    /// Converged temperature map at the optimum.
    pub temp: Vec<f64>,
    /// Frequency ratio vs the nominal-voltage design (Fig. 7 ▲ points).
    pub freq_ratio: f64,
    /// Search-effort counters (runtime-claims bench).
    pub pairs_total: usize,
    pub pairs_pruned_energy: usize,
    pub thermal_solves: usize,
    pub thermal_reused: usize,
}

/// Run Algorithm 2.
#[deprecated(note = "construct flows through `flow::FlowSession::alg2`")]
pub fn thermal_aware_energy_optimization(
    design: &Design,
    cfg: &Config,
    backend: &mut dyn ThermalBackend,
) -> Alg2Result {
    let sta = design.sta();
    let pm = design.power_model();
    let mut arena = StaCacheArena::new();
    unwrap_alg2(run_impl(design, &sta, &pm, cfg, backend, &mut arena))
}

#[deprecated(note = "construct flows through `flow::FlowSession::alg2`")]
pub fn run_with(
    design: &Design,
    sta: &Sta<'_>,
    pm: &PowerModel<'_>,
    cfg: &Config,
    backend: &mut dyn ThermalBackend,
) -> Alg2Result {
    let mut arena = StaCacheArena::new();
    unwrap_alg2(run_impl(design, sta, pm, cfg, backend, &mut arena))
}

/// Batched path, sharing a caller-owned [`StaCacheArena`].
#[deprecated(note = "construct flows through `flow::FlowSession::alg2`")]
pub fn run_with_arena(
    design: &Design,
    sta: &Sta<'_>,
    pm: &PowerModel<'_>,
    cfg: &Config,
    backend: &mut dyn ThermalBackend,
    arena: &mut StaCacheArena,
) -> Alg2Result {
    unwrap_alg2(run_impl(design, sta, pm, cfg, backend, arena))
}

/// The deprecated shims promised an infallible signature; they keep it by
/// panicking on the (config-validated-away) empty-grid error the typed API
/// reports as `FlowError::EmptyVoltageGrid`.
fn unwrap_alg2(r: Result<Alg2Result, FlowError>) -> Alg2Result {
    match r {
        Ok(out) => out,
        Err(e) => panic!("{e}"),
    }
}

/// Default (batched + memoizing) implementation. Bit-identical to the
/// naive path: the batched flat STA prices each candidate with the scalar
/// path's exact arithmetic, the prepared power sweep reuses the very same
/// per-tile `exp` factors, and the arena only interns what the naive path
/// would have rebuilt.
pub(crate) fn run_impl(
    design: &Design,
    sta: &Sta<'_>,
    pm: &PowerModel<'_>,
    cfg: &Config,
    backend: &mut dyn ThermalBackend,
    arena: &mut StaCacheArena,
) -> Result<Alg2Result, FlowError> {
    let vnc = cfg.arch.v_core_nom;
    let vnb = cfg.arch.v_bram_nom;
    let gb = 1.0 + cfg.flow.guardband;
    let d_worst = arena
        .analyze_flat(sta, cfg.thermal.t_max, vnc, vnb)
        .critical_path;
    let nominal_period = d_worst * gb;

    let n = design.dev.n_tiles();
    let core_levels = cfg.vgrid.core_levels();
    let bram_levels = cfg.vgrid.bram_levels();

    let mut best: Option<Alg2Result> = None;
    let mut pairs_pruned_energy = 0usize;
    let mut thermal_solves = 0usize;
    let mut thermal_reused = 0usize;
    // thermal memoization: (total power, converged map)
    let mut memo: Vec<(f64, Vec<f64>)> = Vec::new();
    let reuse_band = if cfg.flow.prune {
        0.1 / cfg.thermal.theta_ja
    } else {
        0.0
    };

    // ---- batched initial pricing: the whole grid in one pass ----
    // Scan order (low-to-high voltage, V_core outer) matches the naive path:
    // low-V candidates seed the energy bound early, making pruning effective.
    let pairs: Vec<(f64, f64)> = core_levels
        .iter()
        .flat_map(|&vc| bram_levels.iter().map(move |&vb| (vc, vb)))
        .collect();
    let pairs_total = pairs.len();
    let d0s: Vec<f64> = sta
        .analyze_flat_many(cfg.flow.t_amb, &pairs)
        .iter()
        .map(|r| r.critical_path)
        .collect();
    // all candidates share the T = T_amb map: pay its exps once
    let flat = vec![cfg.flow.t_amb; n];
    let prep = pm.prepare_temp(&flat);

    for (pi, &(vc, vb)) in pairs.iter().enumerate() {
        // ---- initial loop (T = T_amb): prune hopeless pairs ----
        let d0 = d0s[pi];
        let period0 = d0 * gb;
        let p0 = pm.total_power_prepared(&prep, 1.0 / period0, vc, vb);
        let e0 = p0 * period0;
        if cfg.flow.prune {
            if let Some(b) = &best {
                if e0 > b.energy {
                    pairs_pruned_energy += 1;
                    continue;
                }
            }
        }
        // ---- temperature-delay feedback to the fixed point ----
        let mut temp = flat.clone();
        let mut period = period0;
        let mut power = p0;
        for _ in 0..cfg.flow.max_iters {
            // thermal step: memoized or solved
            let reused = memo
                .iter()
                .find(|(p, _)| (p - power).abs() < reuse_band)
                .map(|(_, t)| t.clone());
            let t_new = match reused {
                Some(t) => {
                    thermal_reused += 1;
                    t
                }
                None => {
                    thermal_solves += 1;
                    let pmap = pm.power_map(&temp, 1.0 / period, vc, vb);
                    let t = backend.steady_state(&pmap, cfg.flow.t_amb);
                    memo.push((power, t.clone()));
                    t
                }
            };
            let mut dmax = 0.0f64;
            for i in 0..n {
                dmax = dmax.max((t_new[i] - temp[i]).abs());
            }
            temp = t_new;
            let d = arena.analyze(sta, &temp, vc, vb).critical_path;
            period = d * gb;
            power = pm.total_power(&temp, 1.0 / period, vc, vb);
            if dmax <= cfg.thermal.delta_t {
                break;
            }
        }
        let energy = power * period;
        if best.as_ref().map(|b| energy < b.energy).unwrap_or(true) {
            best = Some(Alg2Result {
                v_core: vc,
                v_bram: vb,
                period,
                energy,
                power,
                temp,
                freq_ratio: nominal_period / period,
                pairs_total,
                pairs_pruned_energy: 0,
                thermal_solves: 0,
                thermal_reused: 0,
            });
        }
    }
    let mut out = best.ok_or(FlowError::EmptyVoltageGrid)?;
    out.pairs_pruned_energy = pairs_pruned_energy;
    out.thermal_solves = thermal_solves;
    out.thermal_reused = thermal_reused;
    Ok(out)
}

/// Pre-refactor evaluation path: per-probe flat STA, per-iteration cache
/// rebuilds, per-tile `exp` on every candidate.
#[deprecated(note = "construct flows through `flow::FlowSession::alg2` with `Fidelity::Naive`")]
pub fn run_naive_with(
    design: &Design,
    sta: &Sta<'_>,
    pm: &PowerModel<'_>,
    cfg: &Config,
    backend: &mut dyn ThermalBackend,
) -> Alg2Result {
    unwrap_alg2(run_naive_impl(design, sta, pm, cfg, backend))
}

/// Pre-refactor evaluation path behind `Fidelity::Naive`: per-probe flat
/// STA, per-iteration cache rebuilds, per-tile `exp` on every candidate.
/// Kept (a) as the `--naive` fallback the bench times the batched engine
/// against in the same run, and (b) as the differential baseline the
/// equivalence tests compare to.
pub(crate) fn run_naive_impl(
    design: &Design,
    sta: &Sta<'_>,
    pm: &PowerModel<'_>,
    cfg: &Config,
    backend: &mut dyn ThermalBackend,
) -> Result<Alg2Result, FlowError> {
    let vnc = cfg.arch.v_core_nom;
    let vnb = cfg.arch.v_bram_nom;
    let gb = 1.0 + cfg.flow.guardband;
    let d_worst = sta.analyze_flat(cfg.thermal.t_max, vnc, vnb).critical_path;
    let nominal_period = d_worst * gb;

    let n = design.dev.n_tiles();
    let core_levels = cfg.vgrid.core_levels();
    let bram_levels = cfg.vgrid.bram_levels();

    let mut best: Option<Alg2Result> = None;
    let mut pairs_pruned_energy = 0usize;
    let mut thermal_solves = 0usize;
    let mut thermal_reused = 0usize;
    // thermal memoization: (total power, converged map)
    let mut memo: Vec<(f64, Vec<f64>)> = Vec::new();
    let reuse_band = if cfg.flow.prune {
        0.1 / cfg.thermal.theta_ja
    } else {
        0.0
    };

    // scan low-to-high voltage: low-V candidates (likely optimal) seed the
    // energy bound early, making pruning effective
    let pairs_total = core_levels.len() * bram_levels.len();
    for &vc in &core_levels {
        for &vb in &bram_levels {
            // ---- initial loop (T = T_amb): prune hopeless pairs ----
            let flat = vec![cfg.flow.t_amb; n];
            let d0 = sta.analyze_flat(cfg.flow.t_amb, vc, vb).critical_path;
            let period0 = d0 * gb;
            let p0 = pm.total_power(&flat, 1.0 / period0, vc, vb);
            let e0 = p0 * period0;
            if cfg.flow.prune {
                if let Some(b) = &best {
                    if e0 > b.energy {
                        pairs_pruned_energy += 1;
                        continue;
                    }
                }
            }
            // ---- temperature-delay feedback to the fixed point ----
            let mut temp = flat;
            let mut period = period0;
            let mut power = p0;
            for _ in 0..cfg.flow.max_iters {
                // thermal step: memoized or solved
                let reused = memo
                    .iter()
                    .find(|(p, _)| (p - power).abs() < reuse_band)
                    .map(|(_, t)| t.clone());
                let t_new = match reused {
                    Some(t) => {
                        thermal_reused += 1;
                        t
                    }
                    None => {
                        thermal_solves += 1;
                        let pmap = pm.power_map(&temp, 1.0 / period, vc, vb);
                        let t = backend.steady_state(&pmap, cfg.flow.t_amb);
                        memo.push((power, t.clone()));
                        t
                    }
                };
                let mut dmax = 0.0f64;
                for i in 0..n {
                    dmax = dmax.max((t_new[i] - temp[i]).abs());
                }
                temp = t_new;
                let d = sta.analyze(&temp, vc, vb).critical_path;
                period = d * gb;
                power = pm.total_power(&temp, 1.0 / period, vc, vb);
                if dmax <= cfg.thermal.delta_t {
                    break;
                }
            }
            let energy = power * period;
            if best.as_ref().map(|b| energy < b.energy).unwrap_or(true) {
                best = Some(Alg2Result {
                    v_core: vc,
                    v_bram: vb,
                    period,
                    energy,
                    power,
                    temp,
                    freq_ratio: nominal_period / period,
                    pairs_total,
                    pairs_pruned_energy: 0,
                    thermal_solves: 0,
                    thermal_reused: 0,
                });
            }
        }
    }
    let mut out = best.ok_or(FlowError::EmptyVoltageGrid)?;
    out.pairs_pruned_energy = pairs_pruned_energy;
    out.thermal_solves = thermal_solves;
    out.thermal_reused = thermal_reused;
    Ok(out)
}

/// Naive-path convenience mirror of [`thermal_aware_energy_optimization`]
/// (the CLI's `energy-opt --naive`).
#[deprecated(note = "construct flows through `flow::FlowSession::alg2` with `Fidelity::Naive`")]
pub fn thermal_aware_energy_optimization_naive(
    design: &Design,
    cfg: &Config,
    backend: &mut dyn ThermalBackend,
) -> Alg2Result {
    let sta = design.sta();
    let pm = design.power_model();
    unwrap_alg2(run_naive_impl(design, &sta, &pm, cfg, backend))
}

/// Baseline energy rate: nominal voltages at the worst-case-guaranteed clock
/// (the same clock Algorithm 1's baseline runs), at the thermal fixed point.
#[deprecated(note = "derive from `flow::FlowSession::baseline` (energy = power / f_clk)")]
pub fn baseline_energy(
    design: &Design,
    cfg: &Config,
    backend: &mut dyn ThermalBackend,
) -> (f64, f64) {
    let sta = design.sta();
    let pm = design.power_model();
    let base = super::alg1::fixed_point_impl(
        design,
        &sta,
        &pm,
        cfg,
        backend,
        cfg.arch.v_core_nom,
        cfg.arch.v_bram_nom,
    );
    let period = 1.0 / base.f_clk;
    (base.power * period, base.power)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::design::Effort;
    use crate::thermal::{NativeSolver, ThermalGrid};

    fn setup(t_amb: f64) -> (Design, Config, NativeSolver) {
        let mut cfg = Config::new();
        cfg.flow.t_amb = t_amb;
        cfg.thermal.theta_ja = 2.0;
        let d = Design::build("mkPktMerge", &cfg, Effort::Quick).unwrap();
        let solver = NativeSolver::new(
            ThermalGrid::calibrated(d.dev.rows, d.dev.cols, &cfg.thermal),
            &cfg.thermal,
        );
        (d, cfg, solver)
    }

    /// Direct-impl harness (the session facade is exercised by
    /// `tests/session.rs`; the unit tests pin the algorithm itself).
    fn run(d: &Design, cfg: &Config, backend: &mut dyn ThermalBackend) -> Alg2Result {
        let sta = d.sta();
        let pm = d.power_model();
        let mut arena = StaCacheArena::new();
        run_impl(d, &sta, &pm, cfg, backend, &mut arena).unwrap()
    }

    fn base_energy(d: &Design, cfg: &Config, backend: &mut dyn ThermalBackend) -> f64 {
        let sta = d.sta();
        let pm = d.power_model();
        let b = super::super::alg1::fixed_point_impl(
            d,
            &sta,
            &pm,
            cfg,
            backend,
            cfg.arch.v_core_nom,
            cfg.arch.v_bram_nom,
        );
        b.power / b.f_clk
    }

    #[test]
    fn energy_optimum_trades_frequency_for_energy() {
        let (d, cfg, mut solver) = setup(65.0);
        let res = run(&d, &cfg, &mut solver);
        let base_e = base_energy(&d, &cfg, &mut solver.clone());
        // Fig. 7: substantial energy saving, frequency ratio well below 1
        let saving = 1.0 - res.energy / base_e;
        assert!(
            (0.25..=0.85).contains(&saving),
            "energy saving {saving} (e={} base={})",
            res.energy,
            base_e
        );
        assert!(
            (0.15..=0.95).contains(&res.freq_ratio),
            "freq ratio {}",
            res.freq_ratio
        );
        // the energy point uses lower voltages than nominal
        assert!(res.v_core < cfg.arch.v_core_nom);
    }

    #[test]
    fn pruning_preserves_the_optimum() {
        let (d, mut cfg, mut solver) = setup(65.0);
        cfg.flow.prune = true;
        let fast = run(&d, &cfg, &mut solver.clone());
        cfg.flow.prune = false;
        let slow = run(&d, &cfg, &mut solver);
        assert_eq!(fast.v_core, slow.v_core, "pruning changed V_core");
        assert_eq!(fast.v_bram, slow.v_bram, "pruning changed V_bram");
        let rel = (fast.energy - slow.energy).abs() / slow.energy;
        assert!(rel < 0.02, "energy mismatch {rel}");
        // and it must actually prune + reuse
        assert!(fast.pairs_pruned_energy > fast.pairs_total / 2);
        assert!(fast.thermal_reused > 0);
        assert!(fast.thermal_solves < slow.thermal_solves);
    }

    #[test]
    fn energy_voltage_differs_from_power_voltage() {
        // §IV: the energy flow reaches much lower V_core than the power flow
        // because the clock is allowed to stretch.
        let (d, cfg, mut solver) = setup(65.0);
        let power_res = {
            let sta = d.sta();
            let pm = d.power_model();
            let mut arena = StaCacheArena::new();
            super::super::alg1::run_impl(&d, &sta, &pm, &cfg, &mut solver.clone(), 1.0, &mut arena)
        };
        let energy_res = run(&d, &cfg, &mut solver);
        assert!(
            energy_res.v_core <= power_res.v_core,
            "energy V_core {} vs power V_core {}",
            energy_res.v_core,
            power_res.v_core
        );
    }
}
