//! The paper's flows: Algorithm 1 (thermal-aware voltage selection),
//! Algorithm 2 (thermal-aware energy optimization), the timing-speculative
//! over-scaling flow (§III-D) and the dynamic (sensor-driven) scheme.
//!
//! **Entry point:** [`FlowSession`] — the typed facade that owns the shared
//! state (config, design cache, STA arenas, thermal backends) and exposes
//! one request/outcome pair per algorithm. The positional free functions in
//! [`alg1`] / [`alg2`] / [`overscale`] and the `VoltageLut` sweep
//! constructors are `#[deprecated]` shims kept only so the differential
//! tests can pin the session bit-identical to the pre-session API.

pub mod alg1;
pub mod alg2;
pub mod design;
pub mod dynamic;
pub mod error;
pub mod overscale;
pub mod session;

pub use alg1::Alg1Result;
pub use alg2::Alg2Result;
pub use design::{Design, Effort};
pub use error::FlowError;
pub use session::{
    Alg1Outcome, Alg1Request, Alg2Outcome, Alg2Request, BaselineRequest, Condition, Fidelity,
    FlowSession, LutOutcome, LutRequest, LutSpec, OverscaleOutcome, OverscaleRequest,
    ShmooOutcome, ShmooRequest, StreamOutcome, StreamRequest, TransientOutcome, TransientRequest,
};

// the fault-injection knobs ride on `ShmooRequest`, so re-export them here
pub use crate::faults::FaultSpec;
// the thermal-coupling knobs ride on `StreamRequest` (and the batch
// fleet's `FleetConfig`), so re-export them here too
pub use crate::fleet::trace::CouplingSpec;
