//! The paper's flows: Algorithm 1 (thermal-aware voltage selection),
//! Algorithm 2 (thermal-aware energy optimization), the timing-speculative
//! over-scaling flow (§III-D) and the dynamic (sensor-driven) scheme.

pub mod alg1;
pub mod alg2;
pub mod design;
pub mod dynamic;
pub mod overscale;

pub use alg1::{baseline, thermal_aware_voltage_selection, Alg1Result};
pub use design::{Design, Effort};
