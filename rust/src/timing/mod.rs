//! Per-tile-temperature static timing analysis (the modified-VPR `T` of
//! Algorithms 1/2).
//!
//! The paper modifies VPR "to enable timing analysis at different scenarios
//! using the characterized libraries": every resource instance on a path is
//! priced at its own tile's junction temperature and its rail's voltage, so
//! a path crossing a hotspot is slower than the same path in a cool region
//! (insight: CPs change with (T, V); routing- and logic-bound paths scale
//! differently).
//!
//! Two evaluation modes:
//! * [`Sta::analyze_flat`] — uniform temperature (used for `d_worst` at
//!   T_max and for fast search inner loops): per-connection resource counts
//!   make it O(#connections).
//! * [`Sta::analyze`] — per-tile temperature map: hop chains are priced
//!   tile-by-tile against a per-(resource, tile) delay cache rebuilt per
//!   call, O(#hops + #tiles·#resources).
//!
//! The searches avoid per-probe cache rebuilds through [`batch`]: a
//! [`StaCacheArena`] interns the caches by (quantized V, T-map fingerprint),
//! and `analyze_many`/`analyze_flat_many` price whole candidate slates in
//! one traversal — all bit-identical to the naive entry points above.

pub mod batch;

use crate::arch::Device;
use crate::chardb::{CharTable, Rail, ResourceType};
use crate::netlist::{CellKind, Netlist, NO_NET};
use crate::place::{BlockGraph, Placement};
use crate::route::{Hop, Routing};

pub use batch::{ArenaStats, StaCacheArena};

/// A timing endpoint (path terminus).
#[derive(Clone, Copy, Debug)]
pub struct Endpoint {
    /// Sink cell (FF, BRAM or Output).
    pub cell: u32,
    /// Data arrival time at the endpoint, seconds.
    pub arrival: f64,
    /// True when the path's last leg touches a BRAM (rail attribution).
    pub through_bram: bool,
    /// True when the worst path passes through a DSP slice (MAC datapath —
    /// drives the systolic-array error mapping in `crate::sim`).
    pub through_dsp: bool,
}

/// STA outcome for one (T, V) condition.
#[derive(Clone, Debug)]
pub struct StaResult {
    /// Critical-path delay (max endpoint arrival), seconds.
    pub critical_path: f64,
    /// All endpoint arrivals (slack histograms, over-scaling error model).
    pub endpoints: Vec<Endpoint>,
    /// Critical endpoint cell.
    pub worst_cell: u32,
}

/// Longest BRAM-touching path (Fig. 6 analysis: LU8PEEng CP = 21× this).
pub fn longest_bram_path(res: &StaResult) -> f64 {
    res.endpoints
        .iter()
        .filter(|e| e.through_bram)
        .map(|e| e.arrival)
        .fold(0.0, f64::max)
}

/// Pre-digested connection: where a net's sink cell receives its data.
#[derive(Clone, Copy, Debug)]
struct Conn {
    /// range into `hop_offsets` (flattened, cache-friendly hop pricing)
    hop_start: u32,
    hop_end: u32,
    /// resource hop counts for the flat mode
    n_sb: u16,
    n_cb: u16,
    n_local: u16,
}

/// STA context bound to one placed+routed design.
pub struct Sta<'a> {
    pub nl: &'a Netlist,
    pub bg: &'a BlockGraph,
    pub pl: &'a Placement,
    pub routing: &'a Routing,
    pub dev: &'a Device,
    pub table: &'a CharTable,
    /// per (net, sink-pin occurrence) connection info, indexed by a flat
    /// offset: conn_of[net_start[nid] + sink_index_in_netlist_net]
    conns: Vec<Conn>,
    /// flattened hop pricing: `cache[hop_offsets[i]]` is the hop delay
    hop_offsets: Vec<u32>,
    /// tile index per cell (site resolved once)
    tile_of_cell: Vec<u32>,
    net_start: Vec<u32>,
    order: Vec<u32>,
    /// per (cell, pin): occurrence index of that pin in its net's sink list
    /// (perf: built once; propagate() used to rebuild it per call).
    occ_of_pin: Vec<Vec<u32>>,
}

impl<'a> Sta<'a> {
    pub fn new(
        nl: &'a Netlist,
        bg: &'a BlockGraph,
        pl: &'a Placement,
        routing: &'a Routing,
        dev: &'a Device,
        table: &'a CharTable,
    ) -> Sta<'a> {
        // netlist net → block net
        let mut net_to_bnet = vec![u32::MAX; nl.nets.len()];
        for (bn, &nid) in bg.netlist_net.iter().enumerate() {
            net_to_bnet[nid as usize] = bn as u32;
        }
        let n_tiles = dev.n_tiles();
        let mut conns = Vec::new();
        let mut hop_offsets: Vec<u32> = Vec::new();
        let mut net_start = Vec::with_capacity(nl.nets.len() + 1);
        for (nid, net) in nl.nets.iter().enumerate() {
            net_start.push(conns.len() as u32);
            let bn = net_to_bnet[nid];
            for &(sink, _) in &net.sinks {
                // intra-block fallback: one local mux at the sink's tile
                let local_conn = |hop_offsets: &mut Vec<u32>| {
                    let site = pl.cell_site(bg, sink);
                    let start = hop_offsets.len() as u32;
                    hop_offsets.push(
                        (ResourceType::LocalMux.index() * n_tiles + dev.idx(site.x, site.y))
                            as u32,
                    );
                    Conn {
                        hop_start: start,
                        hop_end: start + 1,
                        n_sb: 0,
                        n_cb: 0,
                        n_local: 1,
                    }
                };
                let conn = if bn == u32::MAX {
                    local_conn(&mut hop_offsets)
                } else {
                    let sink_block = bg.block_of_cell[sink as usize];
                    let bnet = &bg.nets[bn as usize];
                    if sink_block == bnet.driver {
                        local_conn(&mut hop_offsets)
                    } else {
                        let slot = bnet
                            .sinks
                            .binary_search(&sink_block)
                            // detlint: allow(D004) router invariant: every sink block is recorded on its net before STA runs
                            .expect("sink block must be on its net")
                            as u32;
                        let chain = &routing.paths[bn as usize][slot as usize];
                        let count = |r: ResourceType| {
                            chain.iter().filter(|h| h.res == r).count() as u16
                        };
                        let start = hop_offsets.len() as u32;
                        for h in chain {
                            // Checked invariant: routing chains carry only
                            // core-rail mux resources. `analyze_cached` prices
                            // every hop out of the core-rail cache, so a
                            // BRAM (or any cell resource) on a chain would be
                            // silently priced at the wrong rail — corrupt the
                            // timing loudly here instead.
                            debug_assert!(
                                matches!(
                                    h.res,
                                    ResourceType::SbMux
                                        | ResourceType::CbMux
                                        | ResourceType::LocalMux
                                ),
                                "routing chain hop must be a core-rail mux, got {:?} at ({}, {})",
                                h.res,
                                h.x,
                                h.y
                            );
                            hop_offsets.push(
                                (h.res.index() * n_tiles
                                    + dev.idx(h.x as usize, h.y as usize))
                                    as u32,
                            );
                        }
                        Conn {
                            hop_start: start,
                            hop_end: hop_offsets.len() as u32,
                            n_sb: count(ResourceType::SbMux),
                            n_cb: count(ResourceType::CbMux),
                            n_local: count(ResourceType::LocalMux),
                        }
                    }
                };
                conns.push(conn);
            }
        }
        net_start.push(conns.len() as u32);
        let order = nl.levelize();
        let tile_of_cell: Vec<u32> = (0..nl.cells.len())
            .map(|cid| {
                let site = pl.cell_site(bg, cid as u32);
                dev.idx(site.x, site.y) as u32
            })
            .collect();
        let mut occ_of_pin: Vec<Vec<u32>> = nl
            .cells
            .iter()
            .map(|c| vec![0u32; c.inputs.len()])
            .collect();
        for net in nl.nets.iter() {
            for (occ, &(sink, pin)) in net.sinks.iter().enumerate() {
                occ_of_pin[sink as usize][pin as usize] = occ as u32;
            }
        }
        Sta {
            nl,
            bg,
            pl,
            routing,
            dev,
            table,
            conns,
            hop_offsets,
            tile_of_cell,
            net_start,
            order,
            occ_of_pin,
        }
    }

    fn conn(&self, nid: u32, sink_occurrence: usize) -> &Conn {
        &self.conns[self.net_start[nid as usize] as usize + sink_occurrence]
    }

    /// Uniform-temperature analysis (fast path).
    pub fn analyze_flat(&self, t_c: f64, v_core: f64, v_bram: f64) -> StaResult {
        let d = |r: ResourceType| {
            let v = match r.rail() {
                Rail::Core => v_core,
                Rail::Bram => v_bram,
            };
            self.table.delay(r, t_c, v)
        };
        let d_sb = d(ResourceType::SbMux);
        let d_cb = d(ResourceType::CbMux);
        let d_local = d(ResourceType::LocalMux);
        let d_lut = d(ResourceType::Lut);
        let d_ff = d(ResourceType::Ff);
        let d_bram = d(ResourceType::Bram);
        let d_dsp = d(ResourceType::Dsp);
        self.propagate(
            |conn, _sink_cell| {
                conn.n_sb as f64 * d_sb + conn.n_cb as f64 * d_cb + conn.n_local as f64 * d_local
            },
            |kind, _cell| match kind {
                CellKind::Lut(_) => d_lut,
                CellKind::Dsp => d_dsp,
                _ => 0.0,
            },
            |kind, _cell| match kind {
                CellKind::Ff => d_ff,
                CellKind::Bram => d_bram,
                _ => 0.0,
            },
        )
    }

    /// Per-(resource, tile) delay cache for the core rail at one (T map, V).
    /// Exposed so the Algorithm-1/2 searches can memoize caches per voltage
    /// level instead of rebuilding them on every feasibility probe (§Perf);
    /// [`StaCacheArena`] interns these across probes, iterations and whole
    /// ambient sweeps. The fill goes through `CharTable::delay_many`, which
    /// brackets the (shared) voltage once per resource.
    pub fn build_core_cache(&self, temp: &[f64], v_core: f64) -> Vec<f64> {
        let core_res = [
            ResourceType::Lut,
            ResourceType::SbMux,
            ResourceType::CbMux,
            ResourceType::LocalMux,
            ResourceType::Ff,
            ResourceType::Dsp,
        ];
        let n = self.dev.n_tiles();
        let mut cache = vec![0.0f64; 8 * n];
        for &r in &core_res {
            let base = r.index() * n;
            self.table
                .delay_many(r, temp, v_core, &mut cache[base..base + n]);
        }
        cache
    }

    /// BRAM-rail companion of [`Sta::build_core_cache`].
    pub fn build_bram_cache(&self, temp: &[f64], v_bram: f64) -> Vec<f64> {
        let n = self.dev.n_tiles();
        let mut cache = vec![0.0f64; n];
        self.table
            .delay_many(ResourceType::Bram, temp, v_bram, &mut cache);
        cache
    }

    /// Per-tile-temperature analysis. `temp` is indexed by `dev.idx(x, y)`.
    pub fn analyze(&self, temp: &[f64], v_core: f64, v_bram: f64) -> StaResult {
        let core = self.build_core_cache(temp, v_core);
        let bram = self.build_bram_cache(temp, v_bram);
        self.analyze_cached(&core, &bram)
    }

    /// Hop-walk analysis against prebuilt delay caches.
    pub fn analyze_cached(&self, cache: &[f64], bram_cache: &[f64]) -> StaResult {
        let n = self.dev.n_tiles();
        assert_eq!(cache.len(), 8 * n);
        assert_eq!(bram_cache.len(), n);
        let tile_of_cell = |cell: u32| -> usize { self.tile_of_cell[cell as usize] as usize };
        self.propagate(
            |conn, _sink_cell| {
                let mut sum = 0.0;
                for &off in &self.hop_offsets[conn.hop_start as usize..conn.hop_end as usize] {
                    // chains carry only core-rail muxes (checked at Sta::new),
                    // so `cache` (core rail) prices every hop
                    sum += cache[off as usize];
                }
                sum
            },
            |kind, cell| match kind {
                CellKind::Lut(_) => cache[ResourceType::Lut.index() * n + tile_of_cell(cell)],
                CellKind::Dsp => cache[ResourceType::Dsp.index() * n + tile_of_cell(cell)],
                _ => 0.0,
            },
            |kind, cell| match kind {
                CellKind::Ff => cache[ResourceType::Ff.index() * n + tile_of_cell(cell)],
                CellKind::Bram => bram_cache[tile_of_cell(cell)],
                _ => 0.0,
            },
        )
    }

    /// Core propagation. `net_delay(conn, sink_cell)`, `cell_delay(kind, cell)`
    /// (combinational), `launch_delay(kind, cell)` (sequential clk→Q).
    fn propagate<FN, FC, FL>(&self, net_delay: FN, cell_delay: FC, launch_delay: FL) -> StaResult
    where
        FN: Fn(&Conn, u32) -> f64,
        FC: Fn(&CellKind, u32) -> f64,
        FL: Fn(&CellKind, u32) -> f64,
    {
        let nl = self.nl;
        let mut arrival = vec![0.0f64; nl.nets.len()];
        let mut through_bram = vec![false; nl.nets.len()];
        let mut through_dsp = vec![false; nl.nets.len()];
        // launch from sequential sources + PIs
        for (cid, c) in nl.cells.iter().enumerate() {
            if c.output == NO_NET {
                continue;
            }
            match c.kind {
                CellKind::Input => arrival[c.output as usize] = 0.0,
                CellKind::Ff | CellKind::Bram => {
                    arrival[c.output as usize] = launch_delay(&c.kind, cid as u32);
                    through_bram[c.output as usize] = matches!(c.kind, CellKind::Bram);
                }
                _ => {}
            }
        }
        // helper: arrival at a sink pin of `net` (the occ-th sink)
        let pin_arrival = |nid: u32, occ: usize, sink: u32, arrival: &[f64]| -> f64 {
            arrival[nid as usize] + net_delay(self.conn(nid, occ), sink)
        };
        let occ_of_pin = &self.occ_of_pin;
        // combinational propagation
        for &cid in &self.order {
            let c = &nl.cells[cid as usize];
            if matches!(c.kind, CellKind::Output) {
                continue;
            }
            let mut worst = 0.0f64;
            let mut wbram = false;
            let mut wdsp = false;
            for (pin, &inet) in c.inputs.iter().enumerate() {
                let occ = occ_of_pin[cid as usize][pin] as usize;
                let a = pin_arrival(inet, occ, cid, &arrival);
                if a > worst {
                    worst = a;
                    wbram = through_bram[inet as usize];
                    wdsp = through_dsp[inet as usize];
                }
            }
            if c.output != NO_NET {
                let out = c.output as usize;
                arrival[out] = worst + cell_delay(&c.kind, cid);
                through_bram[out] = wbram;
                through_dsp[out] = wdsp || matches!(c.kind, CellKind::Dsp);
            }
        }
        // endpoints: FF D pins, BRAM input pins, POs
        let mut endpoints = Vec::new();
        let mut critical_path = 0.0f64;
        let mut worst_cell = 0u32;
        for (cid, c) in nl.cells.iter().enumerate() {
            let is_endpoint = matches!(c.kind, CellKind::Ff | CellKind::Bram | CellKind::Output);
            if !is_endpoint {
                continue;
            }
            let mut worst = 0.0f64;
            let mut wbram = matches!(c.kind, CellKind::Bram);
            let mut wdsp = false;
            for (pin, &inet) in c.inputs.iter().enumerate() {
                let occ = occ_of_pin[cid][pin] as usize;
                let a = pin_arrival(inet, occ, cid as u32, &arrival);
                if a > worst {
                    worst = a;
                    wbram |= through_bram[inet as usize];
                    wdsp = through_dsp[inet as usize];
                }
            }
            endpoints.push(Endpoint {
                cell: cid as u32,
                arrival: worst,
                through_bram: wbram,
                through_dsp: wdsp,
            });
            if worst > critical_path {
                critical_path = worst;
                worst_cell = cid as u32;
            }
        }
        StaResult {
            critical_path,
            endpoints,
            worst_cell,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chardb::CharDb;
    use crate::config::ArchConfig;
    use crate::netlist::cluster_netlist;
    use crate::place::{place, BlockKind, PlaceOpts};
    use crate::route::route;
    use crate::synth::{benchmark, generate};

    struct Fixture {
        nl: Netlist,
        bg: BlockGraph,
        dev: Device,
        pl: Placement,
        routing: Routing,
        table: CharTable,
    }

    fn fixture(name: &str) -> Fixture {
        let arch = ArchConfig::default();
        let nl = generate(benchmark(name).unwrap());
        let cl = cluster_netlist(&nl, &arch);
        let bg = BlockGraph::build(&nl, &cl);
        let nclb = bg.kinds.iter().filter(|&&k| k == BlockKind::Clb).count();
        let nbram = bg.kinds.iter().filter(|&&k| k == BlockKind::Bram).count();
        let ndsp = bg.kinds.iter().filter(|&&k| k == BlockKind::Dsp).count();
        let nio = bg.kinds.iter().filter(|&&k| k == BlockKind::Io).count();
        let dev = Device::size_for_io(nclb, nbram, ndsp, nio, &arch);
        let pl = place(
            &bg,
            &dev,
            &PlaceOpts {
                seed: 4,
                effort: 0.5,
                max_moves: 60_000,
            },
        );
        let routing = route(&bg, &pl, &dev);
        let table = CharTable::generate(&CharDb::analytic());
        Fixture {
            nl,
            bg,
            dev,
            pl,
            routing,
            table,
        }
    }

    #[test]
    fn cp_positive_and_flat_matches_uniform_map() {
        let f = fixture("mkPktMerge");
        let sta = Sta::new(&f.nl, &f.bg, &f.pl, &f.routing, &f.dev, &f.table);
        let flat = sta.analyze_flat(100.0, 0.8, 0.95);
        assert!(flat.critical_path > 1e-9, "cp = {}", flat.critical_path);
        let uniform = vec![100.0; f.dev.n_tiles()];
        let mapped = sta.analyze(&uniform, 0.8, 0.95);
        let rel = (flat.critical_path - mapped.critical_path).abs() / flat.critical_path;
        assert!(rel < 1e-9, "flat vs uniform-map rel diff {rel}");
    }

    #[test]
    fn cp_monotone_in_temperature_and_voltage() {
        let f = fixture("mkPktMerge");
        let sta = Sta::new(&f.nl, &f.bg, &f.pl, &f.routing, &f.dev, &f.table);
        let d40 = sta.analyze_flat(40.0, 0.8, 0.95).critical_path;
        let d100 = sta.analyze_flat(100.0, 0.8, 0.95).critical_path;
        assert!(d40 < d100, "thermal margin must exist: {d40} vs {d100}");
        // Fig. 2(a): at nominal V the margin from 100→40 °C is ~10–17 %
        let ratio = d40 / d100;
        assert!((0.80..=0.95).contains(&ratio), "margin ratio {ratio}");
        let dv = sta.analyze_flat(40.0, 0.70, 0.95).critical_path;
        assert!(dv > d40, "lower voltage must slow the CP");
    }

    #[test]
    fn hotspot_tile_slows_paths_through_it() {
        let f = fixture("mkPktMerge");
        let sta = Sta::new(&f.nl, &f.bg, &f.pl, &f.routing, &f.dev, &f.table);
        let cool = vec![40.0; f.dev.n_tiles()];
        let base = sta.analyze(&cool, 0.8, 0.95).critical_path;
        // heat every tile: CP must rise; heat one corner: CP must not drop
        let hot = vec![100.0; f.dev.n_tiles()];
        let worst = sta.analyze(&hot, 0.8, 0.95).critical_path;
        assert!(worst > base);
        let mut corner = cool.clone();
        corner[f.dev.idx(1, 1)] = 100.0;
        let c = sta.analyze(&corner, 0.8, 0.95).critical_path;
        assert!(c >= base - 1e-15);
        assert!(c <= worst + 1e-15);
    }

    #[test]
    fn bram_paths_tracked_and_short_in_lu8peeng_style() {
        // use boundtop (small, has 1 bram) for speed; the LU8PEEng-scale
        // check lives in the integration tests
        let f = fixture("mkPktMerge");
        let sta = Sta::new(&f.nl, &f.bg, &f.pl, &f.routing, &f.dev, &f.table);
        let res = sta.analyze_flat(100.0, 0.8, 0.95);
        let bram = longest_bram_path(&res);
        assert!(bram > 0.0, "mkPktMerge has BRAM paths");
        assert!(bram <= res.critical_path + 1e-15);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "routing chain hop must be a core-rail mux")]
    fn malformed_netlist_with_bram_hop_panics_loudly() {
        let mut f = fixture("mkPktMerge");
        // corrupt the routing: inject a BRAM "hop" into the first routed
        // chain — pre-invariant this was silently priced off the core rail
        let bn = f
            .routing
            .paths
            .iter()
            .position(|p| !p.is_empty())
            .expect("mkPktMerge has routed nets");
        f.routing.paths[bn][0].push(Hop {
            res: ResourceType::Bram,
            x: 1,
            y: 1,
        });
        let _ = Sta::new(&f.nl, &f.bg, &f.pl, &f.routing, &f.dev, &f.table);
    }

    #[test]
    fn bram_voltage_only_affects_bram_paths() {
        let f = fixture("mkPktMerge");
        let sta = Sta::new(&f.nl, &f.bg, &f.pl, &f.routing, &f.dev, &f.table);
        let a = sta.analyze_flat(60.0, 0.8, 0.95);
        let b = sta.analyze_flat(60.0, 0.8, 0.80);
        // non-BRAM endpoints unchanged
        for (ea, eb) in a.endpoints.iter().zip(&b.endpoints) {
            if !ea.through_bram && !eb.through_bram {
                assert!((ea.arrival - eb.arrival).abs() < 1e-15);
            }
        }
        assert!(longest_bram_path(&b) > longest_bram_path(&a));
    }
}
