//! Batched, memoizing STA evaluation engine.
//!
//! The paper's Algorithm 1/2 searches are dominated by repeated STA over the
//! (V_core, V_bram, T) grid — the search-optimization story (72 min → 49 s)
//! is a first-class result of the paper, and every probe used to rebuild the
//! per-(resource, tile) delay caches from scratch. Two mechanisms fix that:
//!
//! * [`StaCacheArena`] — interns the [`Sta::build_core_cache`] /
//!   [`Sta::build_bram_cache`] results keyed by *(quantized rail voltage,
//!   temperature-map fingerprint)*, so Algorithm 1's binary search,
//!   Algorithm 2's voltage-grid sweep, `VoltageLut::build`'s ambient sweep
//!   and the over-scaling flow share delay caches instead of rebuilding
//!   them per probe. Uniform-temperature (`analyze_flat`) results are
//!   memoized whole — `d_worst` at (T_max, V_nom) is re-derived dozens of
//!   times across an ambient sweep and never changes.
//! * [`Sta::analyze_many`] / [`Sta::analyze_flat_many`] — batched entry
//!   points that price a whole slate of (V_core, V_bram) candidates in one
//!   pass over the connection/hop arrays: the per-net traversal state is
//!   loaded once and amortized across candidates instead of re-walked per
//!   probe. `analyze_flat_many` is the one Algorithm 2's full-grid initial
//!   pricing runs on; `analyze_many` is its per-tile-map twin (the searches'
//!   feedback loops are one-pair-at-a-time, so today it is exercised by the
//!   differential tests and stands ready for slate-shaped map-mode searches).
//!
//! **Differential-equivalence guarantee.** Every cached or batched result is
//! bit-identical to the naive [`Sta::analyze`] / [`Sta::analyze_flat`]: the
//! arena stores values produced by the exact same cache-build functions, and
//! the batched propagation performs the per-candidate arithmetic in the same
//! order as the scalar propagation (see `tests/batch_sta.rs`).
//!
//! **Cache-key quantization.** Voltages are keyed at a 1 µV quantum
//! ([`V_QUANTUM`]): lossless for the 10 mV VID grid the searches actually
//! probe (`VoltageGrid::levels` snaps to 1 µV for exactly this reason),
//! while collapsing sub-µV float drift from repeated `lo + i*step` axis
//! construction. Temperature maps are keyed by a 64-bit fold of their bit
//! patterns — two *different* maps colliding requires a 2⁻⁶⁴ hash accident,
//! which the differential tests make observable if it ever mattered.
//! An arena is bound to one design's `Sta` (cache geometry is per-device);
//! never share one across designs.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::Arc;

use super::{Sta, StaResult};
use crate::chardb::{Rail, ResourceType};
use crate::netlist::{CellKind, NO_NET};

/// Voltage cache-key quantum (V): 1 µV. See the module docs for why this is
/// lossless for the searches' 10 mV VID grid.
pub const V_QUANTUM: f64 = 1e-6;

#[inline]
fn qv(v: f64) -> i64 {
    (v / V_QUANTUM).round() as i64
}

/// 64-bit fold of a temperature map's bit patterns, built on the same
/// [`crate::util::mix64`] step as the fleet telemetry fingerprint.
pub fn temp_fingerprint(temp: &[f64]) -> u64 {
    let mut acc = 0x51A7_EA9C_0FFE_E000u64 ^ (temp.len() as u64);
    for &t in temp {
        acc = crate::util::mix64(acc, t.to_bits());
    }
    acc
}

/// Hit/miss counters — surfaced by `thermovolt bench` to show where the
/// searches stopped rebuilding state.
#[derive(Clone, Copy, Debug, Default)]
pub struct ArenaStats {
    pub core_hits: usize,
    pub core_misses: usize,
    pub bram_hits: usize,
    pub bram_misses: usize,
    pub flat_hits: usize,
    pub flat_misses: usize,
}

/// Delay caches are retained for at most this many distinct temperature
/// maps (LRU on the map fingerprint). Searches probe many voltages under
/// few maps — Algorithm 1 has one map per outer iteration, Algorithm 2's
/// thermal memo collapses the feedback maps — so a small bound keeps every
/// useful hit while capping memory on pathological runs (`prune = false`
/// gives every feedback iteration of every pair its own map; unbounded
/// retention there would grow to gigabytes on large devices).
const MAX_TEMP_MAPS: usize = 32;

/// Memoized flat (uniform-T) results are capped at this many entries; the
/// searches only ever insert a handful (`d_worst` conditions), so hitting
/// the cap means a caller is sweeping flat conditions — dump and restart
/// rather than grow without bound (a flat result carries a full endpoints
/// vector).
const MAX_FLAT_RESULTS: usize = 256;

/// Interning arena for STA delay caches and flat results. One arena per
/// design; cheap to create, grows with the number of *distinct*
/// (voltage, temperature-map) conditions actually probed, bounded to the
/// `MAX_TEMP_MAPS` most recently used maps and `MAX_FLAT_RESULTS` flat
/// memo entries (eviction only rebuilds — it can never change a result).
#[derive(Default)]
pub struct StaCacheArena {
    // detlint: allow(D001) keyed memo: get/insert/retain only; results never depend on iteration order
    core: HashMap<(i64, u64), Arc<Vec<f64>>>,
    // detlint: allow(D001) keyed memo: get/insert/retain only; results never depend on iteration order
    bram: HashMap<(i64, u64), Arc<Vec<f64>>>,
    // detlint: allow(D001) keyed memo: get/insert/retain only; results never depend on iteration order
    flat: HashMap<(u64, i64, i64), Arc<StaResult>>,
    /// Map fingerprints, least-recently-used first.
    fp_lru: Vec<u64>,
    pub stats: ArenaStats,
}

impl StaCacheArena {
    pub fn new() -> StaCacheArena {
        StaCacheArena::default()
    }

    /// Mark `key` as the most recently used map; evict the oldest map's
    /// delay caches once more than [`MAX_TEMP_MAPS`] are held.
    fn touch_fp(&mut self, key: u64) {
        if let Some(pos) = self.fp_lru.iter().position(|&k| k == key) {
            self.fp_lru.remove(pos);
            self.fp_lru.push(key);
            return;
        }
        self.fp_lru.push(key);
        if self.fp_lru.len() > MAX_TEMP_MAPS {
            let evict = self.fp_lru.remove(0);
            self.core.retain(|&(_, fp), _| fp != evict);
            self.bram.retain(|&(_, fp), _| fp != evict);
        }
    }

    /// Fingerprint a temperature map once per search iteration; pass the key
    /// to [`core_cache`](Self::core_cache) / [`bram_cache`](Self::bram_cache)
    /// so repeated probes under the same map skip the rehash.
    pub fn temp_key(temp: &[f64]) -> u64 {
        temp_fingerprint(temp)
    }

    /// Core-rail delay cache for (`temp`, `v_core`), interned. `key` must be
    /// `Self::temp_key(temp)` for the same `temp` slice.
    pub fn core_cache(
        &mut self,
        sta: &Sta<'_>,
        temp: &[f64],
        key: u64,
        v_core: f64,
    ) -> Arc<Vec<f64>> {
        self.touch_fp(key);
        match self.core.entry((qv(v_core), key)) {
            Entry::Occupied(e) => {
                self.stats.core_hits += 1;
                e.get().clone()
            }
            Entry::Vacant(e) => {
                self.stats.core_misses += 1;
                e.insert(Arc::new(sta.build_core_cache(temp, v_core))).clone()
            }
        }
    }

    /// BRAM-rail companion of [`core_cache`](Self::core_cache).
    pub fn bram_cache(
        &mut self,
        sta: &Sta<'_>,
        temp: &[f64],
        key: u64,
        v_bram: f64,
    ) -> Arc<Vec<f64>> {
        self.touch_fp(key);
        match self.bram.entry((qv(v_bram), key)) {
            Entry::Occupied(e) => {
                self.stats.bram_hits += 1;
                e.get().clone()
            }
            Entry::Vacant(e) => {
                self.stats.bram_misses += 1;
                e.insert(Arc::new(sta.build_bram_cache(temp, v_bram))).clone()
            }
        }
    }

    /// Per-tile-temperature analysis through the arena — bit-identical to
    /// [`Sta::analyze`], but delay caches are reused across calls that share
    /// a (voltage, temperature-map) condition.
    pub fn analyze(
        &mut self,
        sta: &Sta<'_>,
        temp: &[f64],
        v_core: f64,
        v_bram: f64,
    ) -> StaResult {
        let key = temp_fingerprint(temp);
        let core = self.core_cache(sta, temp, key, v_core);
        let bram = self.bram_cache(sta, temp, key, v_bram);
        sta.analyze_cached(&core, &bram)
    }

    /// Memoized uniform-temperature analysis — bit-identical to
    /// [`Sta::analyze_flat`] (it *is* that result, computed once).
    pub fn analyze_flat(
        &mut self,
        sta: &Sta<'_>,
        t_c: f64,
        v_core: f64,
        v_bram: f64,
    ) -> Arc<StaResult> {
        let k = (t_c.to_bits(), qv(v_core), qv(v_bram));
        if let Some(r) = self.flat.get(&k) {
            self.stats.flat_hits += 1;
            return r.clone();
        }
        self.stats.flat_misses += 1;
        if self.flat.len() >= MAX_FLAT_RESULTS {
            self.flat.clear();
        }
        let r = Arc::new(sta.analyze_flat(t_c, v_core, v_bram));
        self.flat.insert(k, r.clone());
        r
    }

    /// Interned entries across all maps (memory introspection for the bench).
    pub fn len(&self) -> usize {
        self.core.len() + self.bram.len() + self.flat.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Candidates per batched-propagation block: bounds the working set
/// (arrival arrays are `#nets × CHUNK`) while keeping the inner
/// per-candidate loops long enough to amortize the traversal.
const CHUNK: usize = 16;

impl<'a> Sta<'a> {
    /// Batched uniform-temperature analysis: price every `(v_core, v_bram)`
    /// candidate in one pass over the connection arrays. Element `i` is
    /// bit-identical to `self.analyze_flat(t_c, pairs[i].0, pairs[i].1)`.
    pub fn analyze_flat_many(&self, t_c: f64, pairs: &[(f64, f64)]) -> Vec<StaResult> {
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(CHUNK) {
            let nc = chunk.len();
            let d = |r: ResourceType, vc: f64, vb: f64| {
                let v = match r.rail() {
                    Rail::Core => vc,
                    Rail::Bram => vb,
                };
                self.table.delay(r, t_c, v)
            };
            let mut d_sb = [0.0f64; CHUNK];
            let mut d_cb = [0.0f64; CHUNK];
            let mut d_local = [0.0f64; CHUNK];
            let mut d_lut = [0.0f64; CHUNK];
            let mut d_ff = [0.0f64; CHUNK];
            let mut d_bram = [0.0f64; CHUNK];
            let mut d_dsp = [0.0f64; CHUNK];
            for (j, &(vc, vb)) in chunk.iter().enumerate() {
                d_sb[j] = d(ResourceType::SbMux, vc, vb);
                d_cb[j] = d(ResourceType::CbMux, vc, vb);
                d_local[j] = d(ResourceType::LocalMux, vc, vb);
                d_lut[j] = d(ResourceType::Lut, vc, vb);
                d_ff[j] = d(ResourceType::Ff, vc, vb);
                d_bram[j] = d(ResourceType::Bram, vc, vb);
                d_dsp[j] = d(ResourceType::Dsp, vc, vb);
            }
            let res = self.propagate_many(
                nc,
                |conn, _sink, nd: &mut [f64]| {
                    for j in 0..nc {
                        nd[j] = conn.n_sb as f64 * d_sb[j]
                            + conn.n_cb as f64 * d_cb[j]
                            + conn.n_local as f64 * d_local[j];
                    }
                },
                |kind, _cell, j| match kind {
                    CellKind::Lut(_) => d_lut[j],
                    CellKind::Dsp => d_dsp[j],
                    _ => 0.0,
                },
                |kind, _cell, j| match kind {
                    CellKind::Ff => d_ff[j],
                    CellKind::Bram => d_bram[j],
                    _ => 0.0,
                },
            );
            out.extend(res);
        }
        out
    }

    /// Batched per-tile-temperature analysis at one shared map: per-candidate
    /// delay caches come from (or are interned into) `arena`, then all
    /// candidates are priced in one walk of the hop arrays, candidates
    /// innermost over a column-interleaved delay matrix. Element `i` is
    /// bit-identical to `self.analyze(temp, pairs[i].0, pairs[i].1)`.
    pub fn analyze_many(
        &self,
        temp: &[f64],
        pairs: &[(f64, f64)],
        arena: &mut StaCacheArena,
    ) -> Vec<StaResult> {
        let n = self.dev.n_tiles();
        let key = temp_fingerprint(temp);
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(CHUNK) {
            let nc = chunk.len();
            let cores: Vec<Arc<Vec<f64>>> = chunk
                .iter()
                .map(|&(vc, _)| arena.core_cache(self, temp, key, vc))
                .collect();
            let brams: Vec<Arc<Vec<f64>>> = chunk
                .iter()
                .map(|&(_, vb)| arena.bram_cache(self, temp, key, vb))
                .collect();
            // column-interleaved hop-delay matrix: mat[(off − mux_lo) * nc + j]
            // is candidate j's delay for hop offset `off` — one contiguous
            // row per hop keeps the candidate loop on adjacent memory. Only
            // the three mux planes are transposed: routing chains carry
            // nothing else (checked at `Sta::new`), so hop offsets always
            // land in [mux_lo, mux_hi).
            let mux_lo = ResourceType::SbMux.index() * n;
            let mux_hi = (ResourceType::LocalMux.index() + 1) * n;
            let mut mat = vec![0.0f64; (mux_hi - mux_lo) * nc];
            for (j, c) in cores.iter().enumerate() {
                for off in mux_lo..mux_hi {
                    mat[(off - mux_lo) * nc + j] = c[off];
                }
            }
            let tile_of = |cell: u32| -> usize { self.tile_of_cell[cell as usize] as usize };
            let res = self.propagate_many(
                nc,
                |conn, _sink, nd: &mut [f64]| {
                    for v in nd.iter_mut() {
                        *v = 0.0;
                    }
                    for &off in
                        &self.hop_offsets[conn.hop_start as usize..conn.hop_end as usize]
                    {
                        let o = off as usize - mux_lo;
                        let row = &mat[o * nc..(o + 1) * nc];
                        for j in 0..nc {
                            nd[j] += row[j];
                        }
                    }
                },
                |kind, cell, j| match kind {
                    CellKind::Lut(_) => cores[j][ResourceType::Lut.index() * n + tile_of(cell)],
                    CellKind::Dsp => cores[j][ResourceType::Dsp.index() * n + tile_of(cell)],
                    _ => 0.0,
                },
                |kind, cell, j| match kind {
                    CellKind::Ff => cores[j][ResourceType::Ff.index() * n + tile_of(cell)],
                    CellKind::Bram => brams[j][tile_of(cell)],
                    _ => 0.0,
                },
            );
            out.extend(res);
        }
        out
    }

    /// Batched companion of `propagate`: identical traversal and identical
    /// per-candidate arithmetic (same additions, same comparisons, in the
    /// same order), with the candidate loop innermost so the net/cell
    /// bookkeeping is loaded once per node instead of once per probe.
    fn propagate_many<FN, FC, FL>(
        &self,
        nc: usize,
        net_delay: FN,
        cell_delay: FC,
        launch_delay: FL,
    ) -> Vec<StaResult>
    where
        FN: Fn(&super::Conn, u32, &mut [f64]),
        FC: Fn(&CellKind, u32, usize) -> f64,
        FL: Fn(&CellKind, u32, usize) -> f64,
    {
        let nl = self.nl;
        let nn = nl.nets.len();
        let mut arrival = vec![0.0f64; nn * nc];
        let mut through_bram = vec![false; nn * nc];
        let mut through_dsp = vec![false; nn * nc];
        // launch from sequential sources + PIs
        for (cid, c) in nl.cells.iter().enumerate() {
            if c.output == NO_NET {
                continue;
            }
            match c.kind {
                CellKind::Input => {} // arrival already 0.0
                CellKind::Ff | CellKind::Bram => {
                    let base = c.output as usize * nc;
                    let is_bram = matches!(c.kind, CellKind::Bram);
                    for j in 0..nc {
                        arrival[base + j] = launch_delay(&c.kind, cid as u32, j);
                        through_bram[base + j] = is_bram;
                    }
                }
                _ => {}
            }
        }
        let occ_of_pin = &self.occ_of_pin;
        let mut nd = vec![0.0f64; nc];
        let mut worst = vec![0.0f64; nc];
        let mut wbram = vec![false; nc];
        let mut wdsp = vec![false; nc];
        // combinational propagation
        for &cid in &self.order {
            let c = &nl.cells[cid as usize];
            if matches!(c.kind, CellKind::Output) {
                continue;
            }
            for j in 0..nc {
                worst[j] = 0.0;
                wbram[j] = false;
                wdsp[j] = false;
            }
            for (pin, &inet) in c.inputs.iter().enumerate() {
                let occ = occ_of_pin[cid as usize][pin] as usize;
                net_delay(self.conn(inet, occ), cid, &mut nd);
                let base = inet as usize * nc;
                for j in 0..nc {
                    let a = arrival[base + j] + nd[j];
                    if a > worst[j] {
                        worst[j] = a;
                        wbram[j] = through_bram[base + j];
                        wdsp[j] = through_dsp[base + j];
                    }
                }
            }
            if c.output != NO_NET {
                let base = c.output as usize * nc;
                let is_dsp = matches!(c.kind, CellKind::Dsp);
                for j in 0..nc {
                    arrival[base + j] = worst[j] + cell_delay(&c.kind, cid, j);
                    through_bram[base + j] = wbram[j];
                    through_dsp[base + j] = wdsp[j] || is_dsp;
                }
            }
        }
        // endpoints: FF D pins, BRAM input pins, POs
        let mut results: Vec<StaResult> = (0..nc)
            .map(|_| StaResult {
                critical_path: 0.0,
                endpoints: Vec::new(),
                worst_cell: 0,
            })
            .collect();
        for (cid, c) in nl.cells.iter().enumerate() {
            let is_endpoint = matches!(c.kind, CellKind::Ff | CellKind::Bram | CellKind::Output);
            if !is_endpoint {
                continue;
            }
            let is_bram = matches!(c.kind, CellKind::Bram);
            for j in 0..nc {
                worst[j] = 0.0;
                wbram[j] = is_bram;
                wdsp[j] = false;
            }
            for (pin, &inet) in c.inputs.iter().enumerate() {
                let occ = occ_of_pin[cid][pin] as usize;
                net_delay(self.conn(inet, occ), cid as u32, &mut nd);
                let base = inet as usize * nc;
                for j in 0..nc {
                    let a = arrival[base + j] + nd[j];
                    if a > worst[j] {
                        worst[j] = a;
                        wbram[j] |= through_bram[base + j];
                        wdsp[j] = through_dsp[base + j];
                    }
                }
            }
            for (j, r) in results.iter_mut().enumerate() {
                r.endpoints.push(super::Endpoint {
                    cell: cid as u32,
                    arrival: worst[j],
                    through_bram: wbram[j],
                    through_dsp: wdsp[j],
                });
                if worst[j] > r.critical_path {
                    r.critical_path = worst[j];
                    r.worst_cell = cid as u32;
                }
            }
        }
        results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn voltage_quantization_separates_vid_levels() {
        // adjacent 10 mV VID levels map to distinct keys; sub-µV drift from
        // `lo + i*step` axis construction collapses to the same key
        assert_ne!(qv(0.55), qv(0.56));
        assert_ne!(qv(0.799), qv(0.800));
        assert_eq!(qv(0.55), qv(0.55 + 1e-8));
        assert_eq!(qv(0.70), qv(0.55 + 15.0 * 0.01));
    }

    #[test]
    fn temp_fingerprint_discriminates_and_repeats() {
        let a = vec![40.0; 64];
        let mut b = a.clone();
        assert_eq!(temp_fingerprint(&a), temp_fingerprint(&b));
        b[17] += 1e-12;
        assert_ne!(temp_fingerprint(&a), temp_fingerprint(&b));
        // length-sensitive even over equal prefixes
        assert_ne!(temp_fingerprint(&a), temp_fingerprint(&a[..63]));
        // -0.0 and 0.0 differ bitwise and must key differently (the maps
        // are °C values, but the key is the bit pattern)
        assert_ne!(temp_fingerprint(&[0.0]), temp_fingerprint(&[-0.0]));
    }
}
