//! thermovolt — reproduction of "FPGA Energy Efficiency by Leveraging
//! Thermal Margin" (Khaleghi, Salamat, Imani, Rosing — CS.AR 2019).
//!
//! A three-layer system: this rust crate is the L3 coordinator (the full
//! FPGA CAD + thermal-aware voltage-scaling flow); the thermal solver and
//! the error-injected ML forward passes are JAX/Pallas programs AOT-lowered
//! to HLO at build time (`make artifacts`) and executed from rust through
//! the PJRT C API (`runtime`). Python never runs on the flow path.
//!
//! Module map (see DESIGN.md §4):
//! * [`chardb`]  — characterized delay/power library (COFFE/HSPICE substitute)
//! * [`arch`]    — tile-grid FPGA device model (Table I architecture)
//! * [`netlist`] — cells/nets/LUT truth tables, BLIF-like text format
//! * [`synth`]   — VTR-profile synthetic benchmark + ML netlist generators
//! * [`place`]   — simulated-annealing placer
//! * [`route`]   — segment-based global router
//! * [`timing`]  — per-tile-(T,V) static timing analysis
//! * [`activity`]— switching-activity estimation (ACE substitute)
//! * [`power`]   — per-tile leakage + dynamic power maps
//! * [`thermal`] — steady-state thermal solver (native + PJRT artifact);
//!   [`thermal::transient`] adds Foster RC-network time-domain dynamics
//!   behind the [`thermal::ThermalDynamics`] trait
//! * [`flow`]    — Algorithms 1 & 2 + voltage over-scaling flow, fronted by
//!   the typed [`flow::FlowSession`] facade (owns the design cache, STA
//!   arenas and thermal backends; every CLI/report/fleet caller goes
//!   through it)
//! * [`sim`]     — post-P&R timing simulation / error injection
//! * [`faults`]  — undervolt fault injector (clustered BRAM bit flips,
//!   config-cell upsets fit against `chardb`) + per-device undervolt shmoo
//!   and the measured-guardband store the fleet exploits
//! * [`ml`]      — LeNet + HD over-scaling workloads (PJRT-driven)
//! * [`runtime`] — PJRT client wrapper around the `xla` crate (feature `pjrt`)
//! * [`coordinator`] — online (sensor-driven) dynamic voltage controller;
//!   the plant is selectable (first-order legacy or exact RC transient with
//!   a predictive guardband)
//! * [`fleet`]   — multi-device datacenter fleet simulator: event-driven
//!   thermal-aware scheduler (arrival/finish/migration events) + the
//!   three-way rail-provisioning policy engine (static / dynamic /
//!   overscaled-dynamic); [`fleet::stream`] adds the online streaming
//!   service — open Poisson arrivals with SLA deadlines, priority-tiered
//!   admission control (shed/degrade) and a rack autoscaler under a fleet
//!   power cap, sharded per rack with a deterministic cross-shard merge
//! * [`timing::batch`] — batched, memoizing STA engine shared by every search
//! * [`benchkit`] — in-repo perf harness (`thermovolt bench` → BENCH_search.json)
//! * [`report`]  — regenerates every paper table/figure
//! * [`analysis`]— detlint, the determinism & correctness lint
//!   (`thermovolt lint` / the `detlint` bin; CI gate)

// The crate predates clippy in CI; these style lints fire all over the
// numeric kernels (index-heavy grid sweeps) where the "fix" would hurt
// readability. `too_many_arguments` and `type_complexity` were dropped when
// the session facade replaced the long positional flow signatures with
// request structs (PR 4).
#![allow(
    clippy::needless_range_loop,
    clippy::many_single_char_names,
    clippy::manual_range_contains,
    clippy::new_without_default
)]

pub mod activity;
pub mod analysis;
pub mod arch;
pub mod benchkit;
pub mod chardb;
pub mod config;
pub mod faults;
pub mod fleet;
pub mod flow;
pub mod ml;
pub mod netlist;
pub mod place;
pub mod power;
pub mod coordinator;
pub mod report;
pub mod route;
pub mod runtime;
pub mod sim;
pub mod synth;
pub mod thermal;
pub mod timing;
pub mod util;
