//! thermovolt CLI — the L3 leader entrypoint.
//!
//! ```text
//! thermovolt characterize                         build + save the chardb
//! thermovolt bench-info                           benchmark suite summary
//! thermovolt power-opt  --bench <b> [--tamb T] [--theta X]  Algorithm 1
//! thermovolt energy-opt --bench <b> [--tamb T]              Algorithm 2
//! thermovolt overscale  --bench <b> --rate R                §III-D flow
//! thermovolt report --table1|--fig2|--fig3|--fig4|--table2|--fig6|--fig7
//!                   |--fig8|--runtime|--leakage|--all  [--full]
//! thermovolt serve  --bench <b> [--transient]     dynamic controller demo
//! thermovolt serve  --stream [--bench <b>] [--scenario <name>] [--racks N]
//!                   [--devices-per-rack N] [--rate HZ] [--duration-s T]
//!                   [--deadline-slack X] [--power-cap W] [--horizon-s T]
//!                   [--seed S] [--workers W] [--coupling F] [--lookahead-s T]
//!                   online streaming fleet: open arrivals with SLA
//!                   deadlines, admission control (shed/degrade), rack
//!                   autoscaling under an optional power cap; the N-worker
//!                   run is replayed serially and fingerprint-checked.
//!                   --coupling F couples rack neighbors at exhaust
//!                   fraction F; --lookahead-s T ranks racks by predicted
//!                   temperature over the next T seconds
//! thermovolt shmoo  --bench <b> [--devices N] [--seed S] [--workers W]
//!                   [--corners K] [--t-lo T] [--t-hi T] [--out F]
//!                   per-device undervolt shmoo: learns measured guardbands
//!                   against injected faults; --out persists the
//!                   GuardbandStore as TOML
//! thermovolt fleet  --devices N --jobs M --scenario <name>
//!                   [--seed S] [--workers W] [--benches a,b] [--horizon-s T]
//!                   [--policy static|dynamic|overscaled] [--overscale-rate R]
//!                   [--transient] [--rc-stages N] [--measured-guardbands]
//!                   [--coupling F] [--lookahead-s T]
//!                                                 datacenter fleet simulation
//!                                                 (RC thermal transients;
//!                                                 measured per-unit margins;
//!                                                 --coupling couples rack
//!                                                 neighbors, --lookahead-s
//!                                                 places on predicted-
//!                                                 coolest-over-horizon)
//! thermovolt bench  [--quick] [--bench <b>] [--out F] [--fleet-out F]
//!                   [--transient-out F] [--faults-out F] [--stream-out F]
//!                   [--coupling-out F]
//!                   perf harness: Alg1 / Alg2 (batched vs --naive path,
//!                   bit-checked) / LUT build / fleet; emits
//!                   BENCH_search.json + a ≥2048-device BENCH_fleet.json +
//!                   the thermal-inertia sweep BENCH_transient.json + the
//!                   fault-injection/guardband sweep BENCH_faults.json +
//!                   the streaming-fleet bench BENCH_stream.json + the
//!                   thermal co-scheduling bench BENCH_coupling.json
//! thermovolt e2e    [--full]                      full-pipeline headline run
//! thermovolt lint   [--json] [--graph dot|json] [--root DIR] [--config FILE]
//!                   detlint: determinism & correctness static analysis
//!                   (rules D000-D007 + unit rules U1001-U1003; exits
//!                   non-zero on findings; --graph prints the crate call
//!                   graph with FlowSession-reachable fns marked)
//! ```

use anyhow::Result;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use thermovolt::config::Config;
use thermovolt::coordinator::{mean_power, DynamicController, PlantModel, Tsd};
use thermovolt::thermal::RcNetwork;
use thermovolt::fleet::policy::PolicyKind;
use thermovolt::fleet::telemetry::FleetTelemetry;
use thermovolt::fleet::trace::Scenario;
use thermovolt::fleet::{Fleet, FleetConfig};
use thermovolt::flow::{
    Alg1Request, Alg2Request, BaselineRequest, CouplingSpec, Effort, Fidelity, FlowSession,
    LutRequest, LutSpec, OverscaleRequest, ShmooRequest, StreamRequest,
};
use thermovolt::report;
use thermovolt::synth;
use thermovolt::util::cli::Args;
use thermovolt::util::table::{f2, f3, mv, mw, pct, Table};

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse the shared condition flags. Unparseable values are hard errors —
/// they used to fall back to the default silently, so a typo'd `--tamb`
/// ran the whole flow at the wrong corner without a word.
fn config_from(args: &Args) -> Result<Config> {
    let mut cfg = match args.opt("config") {
        Some(path) => Config::from_file(Path::new(path)).unwrap_or_else(|e| {
            eprintln!("warning: {e}; using defaults");
            Config::new()
        }),
        None => Config::new(),
    };
    fn parsed(flag: &str, v: &str) -> Result<f64> {
        v.parse()
            .map_err(|_| anyhow::anyhow!("--{flag} {v}: not a number"))
    }
    if let Some(t) = args.opt("tamb") {
        cfg.flow.t_amb = parsed("tamb", t)?;
    }
    if let Some(t) = args.opt("theta") {
        cfg.thermal.theta_ja = parsed("theta", t)?;
    }
    if let Some(a) = args.opt("alpha") {
        cfg.flow.alpha_in = parsed("alpha", a)?;
    }
    Ok(cfg)
}

fn effort_from(args: &Args) -> Effort {
    if args.flag("full") {
        Effort::Full
    } else {
        Effort::Quick
    }
}

fn run(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let effort = effort_from(args);
    let results = Path::new("results");
    match args.subcommand.as_str() {
        "characterize" => {
            let t = report::characterize(&cfg)?;
            println!(
                "characterized 8 resources × {} temps × {} volts → {}",
                t.temps.len(),
                t.volts.len(),
                cfg.artifacts_dir.join("chardb.bin").display()
            );
        }
        "bench-info" => {
            let mut t = Table::new(
                "Benchmark suite (VTR-profile synthetic)",
                &["name", "domain", "LUTs", "FFs", "BRAMs", "DSPs", "depth"],
            );
            for name in synth::benchmark_names() {
                let p = synth::benchmark(name)?;
                t.row(vec![
                    p.name.into(),
                    p.domain.into(),
                    p.luts.to_string(),
                    p.ffs.to_string(),
                    p.brams.to_string(),
                    p.dsps.to_string(),
                    p.depth.to_string(),
                ]);
            }
            println!("{}", t.render());
        }
        "power-opt" => {
            let bench = args.opt_or("bench", "mkDelayWorker");
            let mut session = FlowSession::with_effort(cfg.clone(), effort)?;
            let design = session.design(bench)?;
            println!(
                "design {bench}: {}x{} device",
                design.dev.rows, design.dev.cols
            );
            let r = session.alg1(Alg1Request::new(bench))?.result;
            let base = session.baseline(BaselineRequest::new(bench))?.result;
            println!(
                "T_amb={:.0}C  d_worst={:.2}ns  f={:.1}MHz",
                cfg.flow.t_amb,
                r.d_worst * 1e9,
                r.f_clk / 1e6
            );
            println!(
                "V = ({} mV, {} mV)  power {} mW vs baseline {} mW  →  {} % saving",
                mv(r.v_core),
                mv(r.v_bram),
                mw(r.power),
                mw(base.power),
                pct(1.0 - r.power / base.power)
            );
            for (i, it) in r.iters.iter().enumerate() {
                println!(
                    "  iter {}: V=({}, {}) mV  P={} mW  Tj={} C  {} s  ({} evals)",
                    i + 1,
                    mv(it.v_core),
                    mv(it.v_bram),
                    mw(it.power),
                    f2(it.t_junct),
                    f3(it.time_s),
                    it.evals
                );
            }
        }
        "energy-opt" => {
            let bench = args.opt_or("bench", "mkDelayWorker");
            let mut cfg = cfg.clone();
            if args.opt("tamb").is_none() {
                cfg.flow.t_amb = 65.0;
            }
            let mut session = FlowSession::with_effort(cfg, effort)?;
            // --naive: pre-refactor per-probe evaluation path (bit-identical
            // results; kept for the bench comparison and as a fallback)
            let fidelity = if args.flag("naive") {
                Fidelity::Naive
            } else {
                Fidelity::Fast
            };
            let r = session
                .alg2(Alg2Request {
                    fidelity,
                    ..Alg2Request::new(bench)
                })?
                .result;
            let base = session.baseline(BaselineRequest::new(bench))?.result;
            let (base_e, base_p) = (base.power / base.f_clk, base.power);
            println!(
                "V = ({}, {}) mV  period {:.2} ns (freq ratio {})  P={} mW",
                mv(r.v_core),
                mv(r.v_bram),
                r.period * 1e9,
                f2(r.freq_ratio),
                mw(r.power)
            );
            println!(
                "energy {:.3} nJ/cycle vs baseline {:.3} nJ/cycle ({} % saving; baseline {} mW)",
                r.energy * 1e9,
                base_e * 1e9,
                pct(1.0 - r.energy / base_e),
                mw(base_p)
            );
            println!(
                "search: {} pairs, {} pruned, {} thermal solves, {} reused",
                r.pairs_total, r.pairs_pruned_energy, r.thermal_solves, r.thermal_reused
            );
        }
        "overscale" => {
            let bench = args.opt_or("bench", "lenet_systolic");
            let rate = args.opt_f64("rate", 1.2);
            // the session resolves accelerator profiles (lenet_systolic,
            // hd_engine) and suite benchmarks through one name space
            let mut session = FlowSession::with_effort(cfg.clone(), effort)?;
            let base = session.baseline(BaselineRequest::new(bench))?.result;
            let o = session.overscale(OverscaleRequest::new(bench, rate))?;
            println!(
                "rate {rate}: V=({}, {}) mV  saving {} %  mean violation rate {:.3e}  hard {:.4}",
                mv(o.alg1.v_core),
                mv(o.alg1.v_bram),
                pct(1.0 - o.alg1.power / base.power),
                o.error.mean_rate,
                o.error.hard_fraction
            );
        }
        "serve" => {
            // --stream: the online streaming fleet front door — open
            // arrivals with SLA deadlines, priority-tiered admission
            // control and rack autoscaling under an optional power cap.
            // Without the flag, the original single-device controller demo.
            if args.flag("stream") {
                let bench = args.opt_or("bench", "sha");
                let scen_name = args.opt_or("scenario", "diurnal");
                let scenario = Scenario::from_name(scen_name).ok_or_else(|| {
                    let names: Vec<&str> =
                        Scenario::all().iter().map(|s| s.name()).collect();
                    anyhow::anyhow!(
                        "unknown scenario `{scen_name}` (one of: {})",
                        names.join(", ")
                    )
                })?;
                let mut req = StreamRequest::new(bench);
                req.scenario = scenario;
                req.racks = args.opt_usize("racks", req.racks);
                req.devices_per_rack =
                    args.opt_usize("devices-per-rack", req.devices_per_rack);
                req.arrival_rate_hz = args.opt_f64("rate", req.arrival_rate_hz);
                req.duration_mean_ms =
                    args.opt_f64("duration-s", req.duration_mean_ms / 1e3) * 1e3;
                req.deadline_slack = args.opt_f64("deadline-slack", req.deadline_slack);
                req.power_cap_w = args.opt_f64("power-cap", req.power_cap_w);
                req.horizon_ms = args.opt_f64("horizon-s", req.horizon_ms / 1e3) * 1e3;
                req.seed = args.opt_u64("seed", req.seed);
                req.workers = args.opt_usize("workers", 4).max(1);
                let coupling_f = args.opt_f64("coupling", 0.0);
                if coupling_f > 0.0 {
                    req.coupling = CouplingSpec::rack(coupling_f);
                }
                req.lookahead_ms =
                    args.opt_f64("lookahead-s", req.lookahead_ms / 1e3) * 1e3;
                req.effort = Some(effort);
                let (t_base, theta) = scenario.corner();
                println!(
                    "stream: {} racks x {} devices, scenario {} ({t_base} C corner, theta_JA {theta} C/W), {:.1} jobs/s over {:.0} s, seed {:#x}, {} worker(s)",
                    req.racks,
                    req.devices_per_rack,
                    scenario.name(),
                    req.arrival_rate_hz,
                    req.horizon_ms / 1e3,
                    req.seed,
                    req.workers
                );
                let mut session = FlowSession::with_effort(cfg.clone(), effort)?;
                // detlint: allow(D003) CLI progress display only; never reaches results
                let t0 = Instant::now();
                let o = session.stream(req.clone())?;
                println!(
                    "stream done in {:.1} s: {} offered, {} admitted, makespan {:.0} s",
                    t0.elapsed().as_secs_f64(),
                    o.telemetry.offered,
                    o.telemetry.admitted,
                    o.telemetry.makespan_ms / 1e3
                );
                if req.workers > 1 {
                    let serial = session.stream(StreamRequest { workers: 1, ..req })?;
                    anyhow::ensure!(
                        serial.fingerprint == o.fingerprint
                            && serial.telemetry.decision_fingerprint
                                == o.telemetry.decision_fingerprint,
                        "{}-worker stream run diverged from the serial replay",
                        o.workers
                    );
                    println!(
                        "serial replay bit-identical (fingerprint {:#018x})",
                        o.fingerprint
                    );
                }
                std::fs::create_dir_all(results)?;
                let t = report::stream_table(&o.telemetry);
                t.emit(results, "stream")?;
                println!("{}", t.render());
                return Ok(());
            }
            let bench = args.opt_or("bench", "mkPktMerge");
            let mut session = FlowSession::with_effort(cfg.clone(), effort)?;
            println!("building (T → V) lookup table for {bench}…");
            let lut = session
                .voltage_lut(LutRequest::new(
                    bench,
                    LutSpec::Sweep {
                        t_amb_lo: 0.0,
                        t_amb_hi: 80.0,
                        step_c: 10.0,
                    },
                ))?
                .lut;
            let design = session.design(bench)?;
            for e in &lut.entries {
                println!(
                    "  Tj <= {:>5.1} C → V=({}, {}) mV   P={} mW",
                    e.t_junct,
                    mv(e.v_core),
                    mv(e.v_bram),
                    mw(e.power)
                );
            }
            // ambient cycle: 20 → 55 → 20 °C over 3 minutes (sim time)
            let sta = design.sta();
            let pm = design.power_model();
            let f_clk = {
                let d = sta
                    .analyze_flat(cfg.thermal.t_max, cfg.arch.v_core_nom, cfg.arch.v_bram_nom)
                    .critical_path;
                1.0 / (d * (1.0 + cfg.flow.guardband))
            };
            let n = design.dev.n_tiles();
            let theta = cfg.thermal.theta_ja;
            // --transient: the RC thermal-network plant with the guardband
            // on predicted peak temperature (default: the legacy
            // instantaneous first-order relaxation)
            let plant = if args.flag("transient") {
                PlantModel::rc(RcNetwork::foster(theta, 3000.0, 2))
            } else {
                PlantModel::FirstOrder
            };
            let controller = DynamicController {
                lut: Arc::new(lut),
                theta_ja: theta,
                tau_ms: 3000.0,
                margin: cfg.flow.sensor_margin,
                tsd: Tsd::default(),
                plant,
                power_fn: move |vc: f64, vb: f64, tj: f64| {
                    let tmap = vec![tj; n];
                    pm.total_power(&tmap, f_clk, vc, vb)
                },
            };
            let trace = vec![(0.0, 20.0), (90_000.0, 55.0), (180_000.0, 20.0)];
            let log = controller.run(&trace, 1.0, 5_000.0)?;
            println!("t(s)  T_amb  T_j    V_core  V_bram  P(mW)");
            for s in &log {
                println!(
                    "{:>5.0}  {:>5.1}  {:>5.1}  {:>6.0}  {:>6.0}  {:>6.0}{}",
                    s.t_ms / 1000.0,
                    s.t_amb,
                    s.t_junct,
                    s.v_core * 1000.0,
                    s.v_bram * 1000.0,
                    s.power * 1000.0,
                    if s.violation { "  VIOLATION" } else { "" }
                );
            }
            let violations = log.iter().filter(|s| s.violation).count();
            println!(
                "mean power {} mW, {} violations across {} samples",
                mw(mean_power(&log)),
                violations,
                log.len()
            );
        }
        "shmoo" => {
            // Per-device undervolt characterization campaign: each virtual
            // unit draws its own threshold shift, gets shmoo'd for safe
            // rails at every temperature corner against its sampled fault
            // population, and the smallest safe sensor margin is learned.
            // The resulting GuardbandStore replaces the fleet's fixed
            // sensor margin (`fleet --measured-guardbands`).
            let bench = args.opt_or("bench", "lenet_systolic");
            let mut req = ShmooRequest::new(bench);
            req.devices = args.opt_usize("devices", req.devices);
            req.seed = args.opt_u64("seed", req.seed);
            req.workers = args.opt_usize("workers", req.workers).max(1);
            req.corners = args.opt_usize("corners", req.corners);
            req.t_lo = args.opt_f64("t-lo", req.t_lo);
            req.t_hi = args.opt_f64("t-hi", req.t_hi);
            // --theta is already folded into the session config; no override
            req.effort = Some(effort);
            let mut session = FlowSession::with_effort(cfg.clone(), effort)?;
            println!(
                "shmoo: {} units x {} corners over {:.0}-{:.0} C on {bench}, seed {:#x}, {} worker(s)",
                req.devices, req.corners, req.t_lo, req.t_hi, req.seed, req.workers
            );
            // detlint: allow(D003) CLI progress display only; never reaches results
            let t0 = Instant::now();
            let o = session.shmoo(req)?;
            println!(
                "campaign done in {:.1} s (T_amb {:.0} C, theta_JA {:.1} C/W):",
                t0.elapsed().as_secs_f64(),
                o.condition.t_amb_c,
                o.condition.theta_ja
            );
            for r in &o.results {
                let worst = r
                    .corners
                    .iter()
                    .fold((f64::NEG_INFINITY, f64::NEG_INFINITY), |w, c| {
                        (w.0.max(c.v_safe_core), w.1.max(c.v_safe_bram))
                    });
                println!(
                    "  unit {:02}: vth {:+.1} mV  margin {:>4.1} C{}  safe rails ({}, {}) mV  ({} probes)",
                    r.device,
                    r.vth_shift * 1000.0,
                    r.margin_c,
                    if r.capped { " CAPPED" } else { "" },
                    mv(worst.0),
                    mv(worst.1),
                    r.probes
                );
            }
            let mean: f64 = o.results.iter().map(|r| r.margin_c).sum::<f64>()
                / o.results.len().max(1) as f64;
            println!(
                "measured margins: mean {:.2} C vs fixed {:.1} C  (store fingerprint {:#x})",
                mean,
                o.fixed_margin_c,
                o.store.fingerprint()
            );
            std::fs::create_dir_all(results)?;
            report::guardband_table(&o.store, o.fixed_margin_c).emit(results, "guardbands")?;
            // accuracy-vs-rail cliff: where the unprotected curve falls and
            // how far protecting the deepest LeNet layer moves it
            let cliff = |pts: &[thermovolt::faults::AccuracyPoint]| {
                pts.iter()
                    .rev()
                    .find(|p| p.lenet_acc < 0.5)
                    .map(|p| p.v_bram)
            };
            match (cliff(&o.accuracy), cliff(&o.accuracy_protected)) {
                (Some(a), Some(b)) => println!(
                    "accuracy cliff (LeNet < 50 %): {} mV unprotected → {} mV with the deepest layer protected",
                    mv(a),
                    mv(b)
                ),
                _ => println!(
                    "accuracy cliff: not reached within the sweep (all rails above the fault wall)"
                ),
            }
            if let Some(out) = args.opt("out") {
                std::fs::write(out, o.store.to_toml())?;
                println!("guardband store → {out}");
            }
        }
        "report" => {
            let all = args.flag("all");
            std::fs::create_dir_all(results)?;
            // one session for the whole report run: figures share placed
            // designs, STA arenas and thermal backends (fig4/table2/fig6
            // all reuse the same mkDelayWorker implementation, for one)
            let mut session = FlowSession::with_effort(cfg.clone(), effort)?;
            let table = session.char_table().clone();
            if all || args.flag("table1") {
                report::table1(&cfg).emit(results, "table1")?;
            }
            if all || args.flag("fig2") {
                let (a, b, c) = report::fig2(&table);
                a.emit(results, "fig2a")?;
                b.emit(results, "fig2b")?;
                c.emit(results, "fig2c")?;
            }
            if all || args.flag("fig3") {
                let (l, r) = report::fig3(&cfg, effort == Effort::Quick)?;
                l.emit(results, "fig3_left")?;
                r.emit(results, "fig3_right")?;
            }
            if all || args.flag("fig4") {
                report::fig4(&mut session)?.emit(results, "fig4")?;
            }
            if all || args.flag("table2") {
                report::table2(&mut session)?.emit(results, "table2")?;
            }
            if all || args.flag("fig6") {
                let names = synth::benchmark_names();
                report::fig6(&mut session, 40.0, 12.0, &names)?.emit(results, "fig6a")?;
                report::fig6(&mut session, 65.0, 2.0, &names)?.emit(results, "fig6b")?;
            }
            if all || args.flag("fig7") {
                let names = synth::benchmark_names();
                report::fig7(&mut session, &names)?.emit(results, "fig7")?;
            }
            if all || args.flag("fig8") {
                match report::fig8(&mut session) {
                    Ok(t) => t.emit(results, "fig8")?,
                    Err(e) if all => eprintln!("fig8 skipped: {e:#}"),
                    Err(e) => return Err(e),
                }
            }
            if all || args.flag("runtime") {
                report::runtime_claims(&mut session)?.emit(results, "runtime_claims")?;
            }
            if all || args.flag("leakage") {
                report::leakage_fit(&cfg)?.emit(results, "leakage_fit")?;
            }
        }
        "fleet" => {
            // Datacenter fleet simulation: N heterogeneous devices, M design
            // jobs, event-driven thermal-aware scheduling, three-way policy
            // comparison. The job stream is executed twice — serial, then on
            // the work-stealing pool — both to time the parallel speedup and
            // to prove bit-exact determinism.
            let devices = args.opt_usize("devices", 8);
            let jobs = args.opt_usize("jobs", 32);
            let scen_name = args.opt_or("scenario", "diurnal");
            let scenario = Scenario::from_name(scen_name).ok_or_else(|| {
                let names: Vec<&str> = Scenario::all().iter().map(|s| s.name()).collect();
                anyhow::anyhow!("unknown scenario `{scen_name}` (one of: {})", names.join(", "))
            })?;
            let mut fcfg = FleetConfig::new(devices, jobs, scenario);
            fcfg.seed = args.opt_u64("seed", cfg.flow.seed);
            fcfg.workers = args.opt_usize("workers", 0);
            fcfg.horizon_ms = args.opt_f64("horizon-s", fcfg.horizon_ms / 1e3) * 1e3;
            fcfg.effort = effort;
            if let Some(b) = args.opt("benches") {
                fcfg.benches = b.split(',').map(str::to_string).collect();
            }
            fcfg.overscale_rate = args.opt_f64("overscale-rate", 0.0);
            // --transient: RC thermal-network plant + predictive placement
            fcfg.transient = args.flag("transient");
            fcfg.rc_stages = args.opt_usize("rc-stages", fcfg.rc_stages);
            // --measured-guardbands: run the per-unit undervolt shmoo at
            // build time and schedule with learned margins instead of the
            // fixed sensor margin
            fcfg.measured_guardbands = args.flag("measured-guardbands");
            // --coupling F: couple rack neighbors through exhaust recirculation
            // at exhaust fraction F; --lookahead-s T: place each job on the
            // device predicted coolest over the next T seconds (RC forecast)
            // instead of the instantaneous estimate
            let coupling_f = args.opt_f64("coupling", 0.0);
            if coupling_f > 0.0 {
                fcfg.coupling = CouplingSpec::rack(coupling_f);
            }
            fcfg.lookahead_ms = args.opt_f64("lookahead-s", fcfg.lookahead_ms / 1e3) * 1e3;
            if let Some(p) = args.opt("policy") {
                fcfg.policy = PolicyKind::from_name(p).ok_or_else(|| {
                    anyhow::anyhow!("unknown policy `{p}` (one of: static, dynamic, overscaled)")
                })?;
                // `--policy overscaled` WITHOUT a rate flag gets the paper's
                // mid-curve 1.2× budget (Fig. 8: near-zero error). An
                // explicitly passed rate is never overridden — a bad one is
                // rejected by Fleet::build instead of silently replaced.
                if fcfg.policy == PolicyKind::OverscaledDynamic
                    && args.opt("overscale-rate").is_none()
                {
                    fcfg.overscale_rate = 1.2;
                }
            }
            let (t_base, theta) = scenario.corner();
            println!(
                "fleet: {devices} devices, {jobs} jobs, scenario {} ({t_base} C corner, theta_JA {theta} C/W), seed {:#x}, policy {}{}{}",
                scenario.name(),
                fcfg.seed,
                fcfg.policy.name(),
                if fcfg.overscale_rate > 1.0 {
                    format!(" (overscale rate {})", fcfg.overscale_rate)
                } else {
                    String::new()
                },
                if fcfg.transient {
                    format!(", transient RC plant ({} stages)", fcfg.rc_stages)
                } else {
                    String::new()
                }
            );
            println!(
                "building job kinds (P&R + Algorithm-1 LUT per benchmark: {})…",
                fcfg.benches.join(", ")
            );
            // detlint: allow(D003) CLI progress display only; never reaches results
            let t0 = Instant::now();
            let fleet = Fleet::build(fcfg, &cfg)?;
            println!("fleet ready in {:.1} s:", t0.elapsed().as_secs_f64());
            if fleet.specs.len() <= 32 {
                for s in &fleet.specs {
                    let margin = match s.measured_margin_c {
                        Some(m) => format!("margin {m:.1} C (measured; fixed {:.1})", s.margin_c),
                        None => format!("margin {:.1} C", s.margin_c),
                    };
                    println!(
                        "  fpga-{:02}: {}x{} tiles  theta_JA {:.2} C/W  rack +{:.1} C  {margin}  power x{:.3}",
                        s.id, s.grid_edge, s.grid_edge, s.theta_ja, s.rack_offset_c, s.power_scale
                    );
                }
            } else {
                println!("  ({} devices — roster omitted)", fleet.specs.len());
            }

            let plan = fleet.plan();
            if !plan.unplaceable.is_empty() {
                println!(
                    "warning: {} job(s) fit no device and will not run",
                    plan.unplaceable.len()
                );
            }
            // detlint: allow(D003) speedup display; telemetry is fingerprint-checked below
            let t1 = Instant::now();
            let serial = fleet.execute(&plan, 1);
            let serial_s = t1.elapsed().as_secs_f64();
            let workers = fleet.effective_workers();
            // detlint: allow(D003) wall-clock speedup display only
            let t2 = Instant::now();
            let parallel = fleet.execute(&plan, workers);
            let parallel_s = t2.elapsed().as_secs_f64();

            let tel_serial = FleetTelemetry::aggregate(devices, serial);
            let tel = FleetTelemetry::aggregate(devices, parallel)
                .with_unplaceable(plan.unplaceable.len());
            anyhow::ensure!(
                tel_serial.fingerprint() == tel.fingerprint(),
                "parallel and serial telemetry diverged — scheduler nondeterminism"
            );

            std::fs::create_dir_all(results)?;
            report::fleet_table(&tel, &fleet.specs).emit(results, "fleet")?;
            println!(
                "fleet saving vs static worst-case: dynamic {} %, overscaled {} %  (paper Fig. 6: 28.3-36.0 % @40C, 20.0-25.0 % @65C)",
                pct(tel.saving()),
                pct(tel.saving_over())
            );
            if tel.expected_errors > 0.0 {
                println!(
                    "overscaled policy: {:.3e} expected timing errors  quality mean {:.4} / min {:.4}",
                    tel.expected_errors, tel.quality_mean, tel.quality_min
                );
            }
            if fleet.cfg.transient {
                println!(
                    "transient plant: peak overshoot {:.2} C above the instantaneous steady state",
                    tel.peak_overshoot_c
                );
            }
            if fleet.cfg.coupling.enabled() {
                println!(
                    "neighbor coupling: inlet rise mean {:.2} C / max {:.2} C over executed jobs{}",
                    tel.coupling_offset_mean_c,
                    tel.coupling_offset_max_c,
                    if fleet.cfg.lookahead_ms > 0.0 {
                        format!(
                            " (lookahead {:.0} s)",
                            fleet.cfg.lookahead_ms / 1e3
                        )
                    } else {
                        String::new()
                    }
                );
            }
            if fleet.cfg.measured_guardbands {
                let (sum_m, sum_f, n) = fleet.specs.iter().fold((0.0, 0.0, 0usize), |acc, s| {
                    (acc.0 + s.effective_margin_c(), acc.1 + s.margin_c, acc.2 + 1)
                });
                println!(
                    "measured guardbands: mean margin {:.2} C vs fixed {:.2} C",
                    sum_m / n.max(1) as f64,
                    sum_f / n.max(1) as f64,
                );
            }
            println!(
                "violations: {} dyn / {} over  |  injected faults {}  |  migrations {}  unplaceable {}  |  throughput {:.1} jobs/h  makespan {:.0} s  queue p50/p95 {:.1}/{:.1} s",
                tel.violations,
                tel.violations_over,
                tel.injected_faults,
                tel.migrations,
                tel.unplaceable,
                tel.throughput_jobs_per_hour,
                tel.makespan_ms / 1e3,
                tel.queue_p50_ms / 1e3,
                tel.queue_p95_ms / 1e3
            );
            println!(
                "execution: serial {:.2} s → {} workers {:.2} s ({:.1}x speedup, telemetry bit-identical)",
                serial_s,
                workers,
                parallel_s,
                serial_s / parallel_s.max(1e-9)
            );
        }
        "bench" => {
            // Perf harness over the search stack; see benchkit. The Alg2
            // stage runs the batched engine AND the pre-refactor --naive
            // path in the same run, checks the results bit-identical, and
            // reports the speedup. Summary lands in BENCH_search.json.
            let opts = thermovolt::benchkit::BenchOpts {
                quick: args.flag("quick"),
                bench: args.opt_or("bench", "mkPktMerge").to_string(),
            };
            let out = Path::new(args.opt_or("out", "BENCH_search.json")).to_path_buf();
            let s = thermovolt::benchkit::run(&cfg, &opts, &out)?;
            println!(
                "bench summary: alg2 {:.1}x vs naive (bit-identical), fleet {:.1}x on {} workers",
                s.alg2_speedup, s.fleet_speedup, s.fleet_workers
            );
            // datacenter-scale fleet bench (≥2048 devices, three-way policy
            // comparison) → BENCH_fleet.json
            let fleet_out = Path::new(args.opt_or("fleet-out", "BENCH_fleet.json")).to_path_buf();
            let fs = thermovolt::benchkit::run_fleet(&cfg, &opts, &fleet_out)?;
            println!(
                "fleet bench: {} devices / {} jobs, {:.1}x on {} workers, saving dyn {:.1} % / over {:.1} %",
                fs.devices,
                fs.jobs,
                fs.speedup,
                fs.workers,
                fs.saving_dyn * 100.0,
                fs.saving_over * 100.0
            );
            // thermal-inertia sweep: the same fleet under the instantaneous
            // and the RC transient plant → BENCH_transient.json
            let transient_out =
                Path::new(args.opt_or("transient-out", "BENCH_transient.json")).to_path_buf();
            let ts = thermovolt::benchkit::run_transient(&cfg, &opts, &transient_out)?;
            println!(
                "transient bench: saving {:.1} % → {:.1} % under the RC plant ({:+} migrations, peak overshoot {:.2} C)",
                ts.instant_saving * 100.0,
                ts.transient_saving * 100.0,
                ts.delta_migrations,
                ts.transient_peak_overshoot_c
            );
            // undervolt fault-injection / measured-guardband sweep
            // → BENCH_faults.json
            let faults_out =
                Path::new(args.opt_or("faults-out", "BENCH_faults.json")).to_path_buf();
            let fa = thermovolt::benchkit::run_faults(&cfg, &opts, &faults_out)?;
            println!(
                "faults bench: margins mean {:.2} C vs fixed {:.1} C, fleet energy {:.1} → {:.1} J ({:.1} % saved, 0 violations / 0 injected faults)",
                fa.margin_mean_c,
                fa.fixed_margin_c,
                fa.fleet_energy_fixed_j,
                fa.fleet_energy_measured_j,
                fa.fleet_energy_saving * 100.0
            );
            // streaming-fleet bench: open arrivals, serial-vs-8-worker
            // fingerprints, then the same arrivals under a power cap
            // → BENCH_stream.json
            let stream_out =
                Path::new(args.opt_or("stream-out", "BENCH_stream.json")).to_path_buf();
            let st = thermovolt::benchkit::run_stream(&cfg, &opts, &stream_out)?;
            println!(
                "stream bench: {} offered / {} shed uncapped, cap {:.0} W → {} shed / {} degraded / {} SLA misses ({} cap-bound ticks)",
                st.offered,
                st.shed,
                st.cap_w,
                st.capped_shed,
                st.capped_degraded,
                st.capped_sla_violations,
                st.capped_cap_bound_ticks
            );
            // thermal co-scheduling bench: coupled vs uncoupled fleet and the
            // instantaneous vs lookahead planner/autoscaler on a heat wave
            // → BENCH_coupling.json
            let coupling_out =
                Path::new(args.opt_or("coupling-out", "BENCH_coupling.json")).to_path_buf();
            let cp = thermovolt::benchkit::run_coupling(&cfg, &opts, &coupling_out)?;
            println!(
                "coupling bench: coupling {:+.1} J dyn, lookahead {:+.1} J dyn / {} → {} violations; stream SLA {} → {} (fingerprints serial==parallel: fleet {}, stream {})",
                cp.delta_coupling_energy_j,
                cp.delta_lookahead_energy_j,
                cp.coupled_violations,
                cp.lookahead_violations,
                cp.stream_instant_sla,
                cp.stream_lookahead_sla,
                cp.fleet_fingerprint_match,
                cp.stream_fingerprint_match
            );
        }
        "e2e" => {
            // END-TO-END: benchmarks through the full pipeline on the PJRT
            // thermal path; prints the headline metric (EXPERIMENTS.md).
            let names = synth::benchmark_names();
            let run_names: Vec<&str> = if effort == Effort::Quick {
                names
                    .iter()
                    .copied()
                    .filter(|n| !matches!(*n, "mcml" | "bgm" | "LU8PEEng"))
                    .collect()
            } else {
                names
            };
            std::fs::create_dir_all(results)?;
            let mut session = FlowSession::with_effort(cfg.clone(), effort)?;
            let t = report::fig6(&mut session, 40.0, 12.0, &run_names)?;
            t.emit(results, "e2e_fig6a")?;
            let avg = t
                .rows
                .last()
                .ok_or_else(|| anyhow::anyhow!("fig6 produced no rows"))?;
            println!(
                "HEADLINE: avg power saving @40C = {}–{} %  (paper: 28.3–36.0 %)",
                avg[3], avg[4]
            );
        }
        "lint" => {
            // detlint, in-process: same engine as the standalone `detlint`
            // bin the CI gate runs (see analysis/).
            let root = match args.opt("root") {
                Some(r) => Path::new(r).to_path_buf(),
                None => {
                    let mut dir = std::env::current_dir()?;
                    loop {
                        if dir.join("rust/src").is_dir() {
                            break dir;
                        }
                        anyhow::ensure!(
                            dir.pop(),
                            "no repo root found (no ancestor contains rust/src); use --root"
                        );
                    }
                }
            };
            let lint_cfg = match args.opt("config") {
                Some(p) => thermovolt::analysis::LintConfig::from_toml(
                    &std::fs::read_to_string(p)?,
                )
                .map_err(|e| anyhow::anyhow!("{p}: {e}"))?,
                None => {
                    let p = root.join("detlint.toml");
                    if p.is_file() {
                        thermovolt::analysis::LintConfig::from_toml(&std::fs::read_to_string(
                            &p,
                        )?)
                        .map_err(|e| anyhow::anyhow!("{}: {e}", p.display()))?
                    } else {
                        thermovolt::analysis::LintConfig::default()
                    }
                }
            };
            let analysis = thermovolt::analysis::analyze_tree(&root, &lint_cfg)?;
            if let Some(fmt) = args.opt("graph") {
                // artifact surface, not the gate: print the call graph
                // (reachable fns marked) and exit clean
                anyhow::ensure!(
                    fmt == "dot" || fmt == "json",
                    "--graph takes `dot` or `json`"
                );
                let rendered = if fmt == "dot" {
                    analysis.graph.render_dot(&analysis.reachable)
                } else {
                    analysis.graph.render_json(&analysis.reachable)
                };
                print!("{rendered}");
                return Ok(());
            }
            let lint_report = &analysis.report;
            if args.flag("json") {
                print!("{}", lint_report.render_json());
            } else {
                print!("{}", lint_report.render_human());
            }
            if !lint_report.clean() {
                std::process::exit(1);
            }
        }
        "" | "help" => {
            println!(
                "subcommands: characterize | bench-info | power-opt | energy-opt | overscale | report | serve | shmoo | fleet | bench | e2e | lint"
            );
        }
        other => anyhow::bail!("unknown subcommand `{other}` (try `help`)"),
    }
    Ok(())
}
